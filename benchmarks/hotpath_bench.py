"""Hot-path benchmark: per-engine-step memory-management cost, scalar vs
batched fault path.

Drives the MemoryManager through the exact per-step sequence the serving
engine performs on its hottest path — every sequence crosses a block
boundary (a page fault), per-block attention heat feeds DAMON, and the
device block tables are captured — WITHOUT the model forward, so the numbers
isolate the management path the paper's overhead argument is about.

Two per-step routes are measured in the same file:

  * ``scalar``  — the pre-PR path: one ctx build + one policy invocation
    (host interpreter) per fault (``ensure_mapped``), the per-step Python
    ``block_table`` rebuild, and the per-mapping Python access-accounting
    loop (the seed implementations, reproduced below so the baseline stays
    measurable after the optimized paths replaced them in ``core.mm``);
  * ``batched`` — this PR's path: the whole step resolves through ONE
    ``fault_batch`` (one vectorized ctx build + one compiled policy
    invocation), incremental block tables, segment-sum access accounting,
    and the persistent DEVICE-RESIDENT block-table plane fed by dirty-row
    uploads (``repro.serving.tables``) instead of a per-step recapture.

Each cell's table publish runs through a transfer-guard shim
(``_TablePlane``) counting every host->device upload, so the cells report
``crossings_per_step`` (ctx matrices + table transfers) and a STEADY-state
probe (no block-boundary crossing): the dirty-row plane ships ZERO rows on
steady steps while the legacy full-recapture path still ships the whole
``[B, vma]`` stack.  Batched ebpf cells also report
``segment_dispatches_per_step`` — the fused ``lax.scan`` policy executor
must issue <= 1 device dispatch per engine step.

Per (policy, max_batch, mode) cell we report steps/s, faults/s,
policy-invocations/step, MEASURED per-step management wall time (p50/p99
from a log2-bucketed latency histogram — ``repro.obs.Log2Hist``, the same
structure the serving telemetry uses) plus the modeled mgmt_ns for
reference.  ``--json`` writes ``BENCH_hotpath.json`` (the ``make
bench-json`` artifact) including the scalar->batched speedup summary, so
the perf trajectory is tracked from this PR onward.

Two pipeline lanes ride along since the unified-compiler PR:

  * ``executors`` — per-backend batch decision latency for the DEFAULT
    64-region Fig-1 program (900 unrolled insns): host interpreter loop vs
    while+switch JIT vs the segmented predicated chain the hook registry
    now selects (this program used to overflow the 512-insn predicated
    budget and fall back to the JIT);
  * ``cache`` — engine-warmup cost with a cold vs warm cross-session
    artifact cache (fresh HookRegistry + ArtifactCache over one directory,
    twice): the warm session reuses the pickled unroll + the persisted XLA
    executables;
  * ``telemetry`` — the observability overhead lane: the same batched
    workload with telemetry absent vs constructed-but-disabled vs fully on
    (ring + histograms + tracepoints).  The disabled lane is the one the
    CI gate (benchmarks.telemetry_gate) holds within 2% of the absent
    baseline — tracing off must cost ~nothing.

Run:  PYTHONPATH=src python -m benchmarks.hotpath_bench [--json FILE]
"""

from __future__ import annotations

import argparse
import json
import random as _pyrandom
import time

import numpy as np

from repro.core import (HWSpec, MemoryManager, Profile, ProfileRegion,
                        ebpf_mm_program, make_cost_model)
from repro.core.buddy import order_blocks
from repro.core.context import FaultKind
from repro.core.damon import Damon, Region
from repro.core.hooks import HOOK_FAULT
from repro.obs import Log2Hist, Telemetry
from repro.serving.tables import DeviceBlockTables

POLICIES = ("ebpf", "thp", "never")
BATCH_SIZES = (4, 16)
STEPS = 192
WARMUP = 16
N_PROFILE_REGIONS = 32      # realistic multi-region profile -> real search cost


def _profile(vma_blocks: int) -> Profile:
    """Striped multi-region profile over the whole VMA (hot stripes benefit
    from huge pages, cold stripes do not) — the map the Fig-1 program
    searches on every fault."""
    bounds = np.linspace(0, vma_blocks, N_PROFILE_REGIONS + 1).astype(int)
    regions = []
    for i, (a, b) in enumerate(zip(bounds, bounds[1:])):
        if b <= a:
            continue
        hot = i % 4 == 0
        # hot stripes pay for order-1 pages; cold stripes stay base pages —
        # keeps a steady ~1 fault per sequence per step to decide on
        benefit = (0, 150_000, 0, 0) if hot else (0, 0, 0, 0)
        regions.append(ProfileRegion(int(a), int(b), benefit))
    return Profile("app", regions)


def _mk_mm(policy: str, nprocs: int, vma_blocks: int,
           telemetry=None, injector=None) -> MemoryManager:
    cost = make_cost_model(HWSpec(), kv_heads=8, head_dim=128, block_tokens=4)
    mm = MemoryManager(nprocs * vma_blocks + 64, cost,
                       default_mode="never" if policy == "never" else "thp",
                       telemetry=telemetry, injector=injector)
    app = None
    if policy == "ebpf":
        mm.load_profile(_profile(vma_blocks))
        mm.attach_fault_program(
            ebpf_mm_program(max_regions=N_PROFILE_REGIONS))
        app = "app"
    for pid in range(1, nprocs + 1):
        mm.create_process(pid, app=app, vma_blocks=vma_blocks)
    return mm


# ---------------------------------------------------------------------------
# Pre-PR (seed) per-step implementations, kept HERE so the baseline remains
# measurable: the per-mapping Python loops below are what core.mm shipped
# before the incremental tables / segment-sum accounting replaced them.
# ---------------------------------------------------------------------------

def _legacy_block_table(mm: MemoryManager, pid: int,
                        max_blocks: int) -> np.ndarray:
    st = mm.procs[pid]
    t = np.full(max_blocks, -1, dtype=np.int32)
    for m in st.page_table.values():
        size = order_blocks(m.order)
        hi = min(m.logical_start + size, max_blocks)
        base = m.phys_start
        for i in range(m.logical_start, hi):
            t[i] = base + (i - m.logical_start)
    return t


def _legacy_damon_record(d: Damon, heat_per_block: np.ndarray,
                         rng: _pyrandom.Random) -> None:
    """The seed's ``Damon.record``: per-region Python EMA loop and one
    ``random.randint`` per region split (since replaced by the vectorized
    pass in ``core.damon``).  ``rng`` is per-cell so each cell's split
    sequence is hermetic."""
    heat = np.asarray(heat_per_block, dtype=np.float64)
    csum = np.concatenate([[0.0], np.cumsum(heat)])

    def span_sum(a: int, b: int) -> float:
        a = min(a, heat.size)
        b = min(b, heat.size)
        return float(csum[b] - csum[a]) if b > a else 0.0

    for r in d.regions:
        mean = span_sum(r.start, r.end) / max(1, len(r))
        r.nr_accesses = d.ema * mean + (1 - d.ema) * r.nr_accesses
        r.age += 1
    d.windows += 1
    d._merge_regions()
    budget = d.max_nr - len(d.regions)
    if budget > 0:
        out = []
        for r in d.regions:
            if budget > 0 and len(r) >= 2:
                cut = r.start + rng.randint(1, len(r) - 1)
                out.append(Region(r.start, cut, r.nr_accesses, 0))
                out.append(Region(cut, r.end, r.nr_accesses, 0))
                budget -= 1
            else:
                out.append(r)
        d.regions = out
    d.version += 1      # keep the (new) heat cache coherent for queries


def _legacy_record_access(mm: MemoryManager, pid: int,
                          heat_per_block: np.ndarray,
                          rng: _pyrandom.Random) -> None:
    st = mm.procs[pid]
    heat = np.asarray(heat_per_block, dtype=np.float64)
    _legacy_damon_record(st.damon, heat, rng)
    st.accesses += 1
    csum = np.concatenate([[0.0], np.cumsum(heat)])
    for m in st.mappings_sorted():
        lo = min(m.logical_start, heat.size)
        hi = min(m.logical_start + order_blocks(m.order), heat.size)
        if hi > lo and csum[hi] - csum[lo] > 0:
            mm.stats.descriptors_touched += 1
            mm.stats.access_ns += int(mm.cost.access_ns(m.order))


class _TablePlane:
    """The engine's block-table publish path, reproduced at bench scale,
    with a transfer-guard shim: every host->device upload the plane performs
    goes through ``_put`` so the CROSSINGS (transfer events and table rows
    shipped) are counted, not inferred.

    * ``legacy=True``  — the pre-PR engine behavior: re-capture every
      sequence's table on the host and ship the full ``[B, vma]`` stack to
      the device EVERY step, whether anything changed or not;
    * ``legacy=False`` — this PR's plane: a persistent device buffer fed by
      dirty-row uploads (the ``repro.serving.tables`` version protocol);
      rows cross only when the table actually mutated.
    """

    def __init__(self, nslots: int, vma_blocks: int, *, legacy: bool):
        import jax
        import jax.numpy as jnp
        self.legacy = legacy
        self.vma_blocks = vma_blocks
        self.dbt = DeviceBlockTables(nslots, vma_blocks)
        self.buf = jnp.full((nslots, vma_blocks), -1, jnp.int32)
        self.transfers = 0          # host->device transfer events (shim)
        self.rows = 0               # table rows shipped across them
        self._jax = jax
        self._jnp = jnp
        # dirty rows scatter into the persistent buffer on device; idx -1
        # (bucket padding) routes out of bounds and drops
        self._install = jax.jit(
            lambda buf, idx, rows: buf.at[
                jnp.where(idx >= 0, idx, buf.shape[0])
            ].set(rows, mode="drop"))
        # append-only deltas arrive as (row, col, value) triples; pad rows
        # of -1 route out of bounds and drop
        self._install_cells = jax.jit(
            lambda buf, tri: buf.at[
                jnp.where(tri[:, 0] >= 0, tri[:, 0], buf.shape[0]),
                tri[:, 1]
            ].set(tri[:, 2], mode="drop"))

    def _put(self, arr):
        self.transfers += 1
        return self._jax.device_put(arr)

    def publish(self, mm: MemoryManager, pids: list[int]) -> None:
        if self.legacy:
            stack = np.stack([_legacy_block_table(mm, pid, self.vma_blocks)
                              for pid in pids])
            self.buf = self._put(stack)
            self.rows += len(pids)
            return
        didx, drows, _active, tri = self.dbt.sync(mm, pids)
        k, t = len(didx), len(tri)
        if k == 0 and t == 0:
            return                      # steady state: nothing crosses
        if k:
            bucket = 1 << (k - 1).bit_length()
            if bucket > k:              # pad so jit compiles once per bucket
                didx = np.concatenate(
                    [didx, np.full(bucket - k, -1, np.int32)])
                drows = np.concatenate(
                    [drows, np.zeros((bucket - k, self.vma_blocks),
                                     np.int32)])
            self.buf = self._install(self.buf, self._put(didx),
                                     self._put(drows))
            self.rows += k
        if t:
            bucket = 1 << (t - 1).bit_length()
            if bucket > t:
                tri = np.concatenate(
                    [tri, np.full((bucket - t, 3), -1, np.int32)])
            self.buf = self._install_cells(self.buf, self._put(tri))
            self.rows += len(np.unique(tri[:t, 0]))   # row-equivalents


def _drive(mm: MemoryManager, pids: list[int], start: int, steps: int,
           vma_blocks: int, *, batched: bool,
           legacy_rng: _pyrandom.Random | None = None,
           step_hist: Log2Hist | None = None,
           plane: _TablePlane | None = None,
           fault: bool = True) -> None:
    """``steps`` engine-step analogues: fault the next boundary for every
    sequence, feed DAMON, publish the device block tables.  ``step_hist``
    (when given) observes the measured wall time of every individual step;
    ``fault=False`` runs STEADY steps (sequences mid-block, no boundary
    crossing) — the lane that shows the dirty-row plane shipping nothing."""
    # sub-integer heat: the access accounting and DAMON stay exercised but
    # the live-heat bonus does not override the profile's size choices
    heat = np.full(vma_blocks, 0.5)
    if not batched and legacy_rng is None:
        legacy_rng = _pyrandom.Random(0)
    for step in range(start, start + steps):
        t0 = time.perf_counter_ns() if step_hist is not None else 0
        if fault:
            if batched:
                mm.fault_batch([(pid, step, FaultKind.FIRST_TOUCH)
                                for pid in pids])
            else:
                for pid in pids:
                    mm.ensure_mapped(pid, step)
        for pid in pids:
            if batched:
                mm.record_access(pid, heat[:step + 1])
            else:
                _legacy_record_access(mm, pid, heat[:step + 1], legacy_rng)
        if plane is not None:
            plane.publish(mm, pids)
        else:
            for pid in pids:
                (mm.block_table(pid, vma_blocks) if batched
                 else _legacy_block_table(mm, pid, vma_blocks))
        mm.drain_moves()
        mm.tick()
        if step_hist is not None:
            step_hist.observe(time.perf_counter_ns() - t0)


N_WINDOWS = 3     # per mode, interleaved scalar/batched; median reported


class _Cell:
    """One (policy, max_batch, mode) measurement lane with its own mm."""

    def __init__(self, policy: str, max_batch: int, *, batched: bool,
                 steps: int, warmup: int, telemetry=None, injector=None):
        self.policy, self.max_batch, self.batched = policy, max_batch, batched
        self.steps = steps
        self.vma_blocks = N_WINDOWS * steps + warmup + 8
        self.mm = _mk_mm(policy, max_batch, self.vma_blocks,
                         telemetry=telemetry, injector=injector)
        self.pids = list(range(1, max_batch + 1))
        self.pos = 0
        self.windows: list[dict] = []
        self.legacy_rng = _pyrandom.Random(0)   # hermetic per cell
        # scalar lane publishes the pre-PR full-recapture table stack;
        # batched lane runs the persistent dirty-row plane
        self.plane = _TablePlane(max_batch, self.vma_blocks,
                                 legacy=not batched)
        self.steady: dict | None = None
        # measured per-step management wall time across all timed windows
        self.mgmt_hist = Log2Hist()
        # warmup: first faults, compile of the batched policy, damon spin-up
        self._advance(warmup, timed=False)

    def _pred(self):
        ap = self.mm.hooks._hooks.get(HOOK_FAULT)
        return getattr(ap, "pred", None) if ap is not None else None

    def _advance(self, steps: int, *, timed: bool) -> None:
        mm = self.mm
        faults0, mgmt0 = mm.stats.faults, mm.stats.mgmt_ns
        calls0 = mm.hooks.calls[HOOK_FAULT]
        xfer0, rows0 = self.plane.transfers, self.plane.rows
        pred = self._pred()
        disp0 = pred.total_dispatches if pred is not None else 0
        t0 = time.perf_counter()
        _drive(mm, self.pids, self.pos, steps, self.vma_blocks,
               batched=self.batched, legacy_rng=self.legacy_rng,
               step_hist=self.mgmt_hist if timed else None,
               plane=self.plane)
        wall = time.perf_counter() - t0
        self.pos += steps
        if timed:
            pred = self._pred()
            self.windows.append({
                "wall": wall,
                "faults": mm.stats.faults - faults0,
                "calls": mm.hooks.calls[HOOK_FAULT] - calls0,
                "mgmt_ns": mm.stats.mgmt_ns - mgmt0,
                "transfers": self.plane.transfers - xfer0,
                "rows_up": self.plane.rows - rows0,
                "dispatches": (pred.total_dispatches - disp0
                               if pred is not None else None),
            })

    def window(self) -> None:
        self._advance(self.steps, timed=True)

    def steady_probe(self, steps: int = 16) -> dict:
        """Steps where NO sequence crosses a block boundary (the common
        decode step: block_tokens-1 out of block_tokens steps).  The
        dirty-row plane ships NOTHING; the legacy plane still re-publishes
        the full table stack every step."""
        xfer0, rows0 = self.plane.transfers, self.plane.rows
        _drive(self.mm, self.pids, self.pos, steps, self.vma_blocks,
               batched=self.batched, legacy_rng=self.legacy_rng,
               plane=self.plane, fault=False)
        self.pos += steps
        self.steady = {
            "steps": steps,
            "crossings_per_step": (self.plane.transfers - xfer0) / steps,
            "rows_per_step": (self.plane.rows - rows0) / steps,
        }
        return self.steady

    def result(self) -> dict:
        # median window by wall time: robust to host jitter, representative
        # of mid-run sequence lengths for both lanes
        ws = sorted(self.windows, key=lambda w: w["wall"])
        mid = ws[len(ws) // 2]
        if self.steady is None:
            self.steady_probe()
        # host->device crossings: table-plane transfer events (shim-counted)
        # plus one ctx-matrix upload per compiled policy dispatch (scalar
        # policies run the host interpreter — no ctx crosses)
        ctx_up = mid["calls"] if self.batched else 0
        return {
            "crossings_per_step": (ctx_up + mid["transfers"]) / self.steps,
            "table_rows_uploaded_per_step": mid["rows_up"] / self.steps,
            "segment_dispatches_per_step": (
                None if mid["dispatches"] is None
                else mid["dispatches"] / self.steps),
            "steady": self.steady,
            "policy": self.policy,
            "max_batch": self.max_batch,
            "mode": "batched" if self.batched else "scalar",
            "steps": self.steps,
            "steps_per_s": self.steps / mid["wall"],
            "faults_per_s": mid["faults"] / mid["wall"],
            "faults": mid["faults"],
            "policy_invocations_per_step": mid["calls"] / self.steps,
            # MEASURED per-step management wall time (log2-hist percentiles
            # over every timed step) — replaces the constant modeled lane
            "mgmt_wall_p50_ns": self.mgmt_hist.percentile(50),
            "mgmt_wall_p99_ns": self.mgmt_hist.percentile(99),
            # the cost-model's modeled charge for the window, for reference
            "modeled_mgmt_ns": mid["mgmt_ns"],
            "wall_host_s": mid["wall"],
        }


# ---------------------------------------------------------------------------
# Pipeline lanes: executor selection + warm/cold artifact cache
# ---------------------------------------------------------------------------

EXEC_REPEATS = 30


def _fig1_default_setup(max_regions: int = 64):
    """The REALISTIC fault-hook load: the default 64-region Fig-1 program
    over a loaded profile — the case that used to fall off the predicated
    fast path (900 unrolled insns > 512).  ``max_regions`` shrinks the
    verified search bound for quick (smoke) lanes."""
    from repro.core import ArrayMap, MapRegistry, PolicyVM
    maps = MapRegistry()
    m = ArrayMap(64 * 6, name="profile:app")
    _profile(256).load_into(m)
    maps.register(m)
    prog = ebpf_mm_program(max_regions=max_regions)
    rng = np.random.default_rng(7)
    mats = {}
    for b in BATCH_SIZES:
        rows = []
        mm = _mk_mm("ebpf", 1, 256)
        mm.ensure_range(1, 0, 8)
        for addr in rng.integers(8, 256, b):
            rows.append(mm._build_ctx(mm.procs[1], int(addr),
                                      FaultKind.FIRST_TOUCH))
        mats[b] = np.stack(rows)
    return prog, maps, mats, PolicyVM(prog, maps)


def _median_time(fn, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def collect_executors(*, smoke: bool = False) -> dict:
    """Per-backend decision latency for the default Fig-1 program, plus
    which backend the hook registry actually selects."""
    from repro.core import JitPolicy
    from repro.core.hooks import HOOK_FAULT, HookRegistry
    from repro.core.predicate import PredicatedPolicy
    prog, maps, mats, vm = _fig1_default_setup()
    batch_sizes = (4,) if smoke else BATCH_SIZES
    repeats = 8 if smoke else EXEC_REPEATS
    reg = HookRegistry()
    reg.attach(HOOK_FAULT, prog, maps)
    reg.run_batch(HOOK_FAULT, mats[batch_sizes[0]])     # build + compile
    ap = reg._hooks[HOOK_FAULT]
    selected = (f"segmented-predicated({ap.pred.num_segments} segments)"
                if ap.pred is not None else "jit")
    seg = ap.pred
    jit = JitPolicy(prog, maps)
    out = {"program": "ebpf_mm(max_regions=64)",
           "unrolled_insns": seg.unrolled_len if seg else None,
           "selected_backend": selected,
           # the one-dispatch contract: the Fig-1 default's segment PLAN may
           # chain, but the fused lax.scan executor issues ONE dispatch
           "fused": seg.fused if seg else None,
           "scan_stages": seg.scan_stages if seg else None,
           "traced_len": seg.traced_len if seg else None,
           "dispatches_per_batch": seg.dispatches if seg else None,
           "lanes": []}
    for b in batch_sizes:
        mat = mats[b]
        lanes = {
            "interpreter": lambda: [vm.run(r).ret for r in mat],
            "jit_while_switch": lambda: jit.run_batch(mat),
        }
        if seg is not None:
            lanes["segmented_predicated"] = lambda: seg.run_batch(mat)
        for name, fn in lanes.items():
            fn()                                        # warm compile/caches
            t = _median_time(fn, repeats)
            out["lanes"].append({
                "backend": name, "batch": b,
                "us_per_batch": t * 1e6,
                "us_per_decision": t * 1e6 / b,
            })
    return out


def collect_cache(*, smoke: bool = False) -> dict:
    """Warm vs cold engine-warmup: two 'sessions' (fresh HookRegistry +
    ArtifactCache) over one cache directory; the build+first-batch time is
    the engine-construction cost the cross-session cache amortizes.
    Smoke mode shrinks the program's verified search bound so the cold
    compile stays quick."""
    import shutil
    import tempfile
    import jax
    from repro.core.cache import ArtifactCache
    from repro.core.hooks import HOOK_FAULT, HookRegistry
    prog, maps, mats, _vm = _fig1_default_setup(
        max_regions=16 if smoke else 64)
    mat = mats[BATCH_SIZES[0]]
    root = tempfile.mkdtemp(prefix="repro-bench-cache-")
    # enable_xla_cache flips the PROCESS-GLOBAL jax compilation-cache dir;
    # park it on the bench tmpdir only for the duration of the lane
    prev_dir = jax.config.jax_compilation_cache_dir
    try:
        laps = []
        for session in ("cold", "warm"):
            cache = ArtifactCache(root)
            reg = HookRegistry(cache=cache)
            reg.attach(HOOK_FAULT, prog, maps)
            t0 = time.perf_counter()
            reg.run_batch(HOOK_FAULT, mat)
            laps.append({"session": session,
                         "build_plus_first_batch_s":
                             time.perf_counter() - t0,
                         "unroll_misses": cache.stats["unroll_misses"]})
        cold, warm = (laps[0]["build_plus_first_batch_s"],
                      laps[1]["build_plus_first_batch_s"])
        return {"sessions": laps, "warm_speedup": cold / warm}
    finally:
        jax.config.update("jax_compilation_cache_dir", prev_dir)
        shutil.rmtree(root, ignore_errors=True)


TELEMETRY_LANES = ("none", "off", "on", "res")


def collect_telemetry(*, smoke: bool = False) -> dict:
    """Observability/resilience-overhead lane: the batched ebpf workload with
    (a) no telemetry object at all, (b) a constructed-but-DISABLED
    Telemetry (what a binary linking the subsystem but not tracing pays),
    (c) telemetry fully on (ring + histograms + every tracepoint), and
    (d) the resilience machinery linked but DISARMED — a zero-rate
    FailureInjector wired through the hook registry plus the (always-on)
    supervisor/containment path, no telemetry.

    Windows interleave across the lanes so host drift hits them alike;
    median steps/s per lane.  ``off_over_none`` and ``res_over_none`` are
    the numbers the CI overhead gate holds >= 0.98 (tracing off and chaos
    disarmed both cost ~nothing)."""
    from repro.resilience import FailureInjector
    steps = 48 if smoke else 96
    warmup = 8 if smoke else WARMUP
    b = 4
    tels = {"none": None, "off": Telemetry(enabled=False), "on": Telemetry(),
            "res": None}
    injs = {lane: None for lane in TELEMETRY_LANES}
    injs["res"] = FailureInjector(0, {})            # constructed, disarmed
    cells = {lane: _Cell("ebpf", b, batched=True, steps=steps, warmup=warmup,
                         telemetry=tels[lane], injector=injs[lane])
             for lane in TELEMETRY_LANES}
    for _ in range(N_WINDOWS):
        for lane in TELEMETRY_LANES:
            cells[lane].window()
    out = {"steps_per_lane": steps, "lanes": {}}
    for lane in TELEMETRY_LANES:
        r = cells[lane].result()
        out["lanes"][lane] = {
            "steps_per_s": r["steps_per_s"],
            "mgmt_wall_p50_ns": r["mgmt_wall_p50_ns"],
            "mgmt_wall_p99_ns": r["mgmt_wall_p99_ns"],
        }
    base = out["lanes"]["none"]["steps_per_s"]
    out["off_over_none"] = out["lanes"]["off"]["steps_per_s"] / base
    out["on_over_none"] = out["lanes"]["on"]["steps_per_s"] / base
    out["res_over_none"] = out["lanes"]["res"]["steps_per_s"] / base
    tel_on = tels["on"]
    out["on_ring"] = tel_on.ring.snapshot()
    return out


def collect(*, smoke: bool = False) -> dict:
    batch_sizes = (4,) if smoke else BATCH_SIZES
    steps = 48 if smoke else STEPS
    warmup = 8 if smoke else WARMUP
    cells = []
    for policy in POLICIES:
        for b in batch_sizes:
            # scalar/batched windows interleave so slow host drift (thermal,
            # neighbors) hits both modes alike; median-of-N per mode
            pair = [_Cell(policy, b, batched=False, steps=steps,
                          warmup=warmup),
                    _Cell(policy, b, batched=True, steps=steps,
                          warmup=warmup)]
            for _ in range(N_WINDOWS):
                for cell in pair:
                    cell.window()
            cells.extend(c.result() for c in pair)
    speedup = {}
    for policy in POLICIES:
        for b in batch_sizes:
            pr = {c["mode"]: c for c in cells
                  if c["policy"] == policy and c["max_batch"] == b}
            speedup[f"{policy}_b{b}"] = (pr["batched"]["steps_per_s"]
                                         / pr["scalar"]["steps_per_s"])
    return {"bench": "hotpath", "steps_per_cell": steps, "cells": cells,
            "speedup_batched_over_scalar": speedup,
            "executors": collect_executors(smoke=smoke),
            "cache": collect_cache(smoke=smoke),
            "telemetry": collect_telemetry(smoke=smoke)}


def main(smoke: bool = False) -> list[str]:
    out = collect(smoke=smoke)
    lines = []
    for c in out["cells"]:
        us_per_step = 1e6 / c["steps_per_s"]
        lines.append(
            f"hotpath_{c['policy']}_b{c['max_batch']}_{c['mode']},"
            f"{us_per_step:.1f},"
            f"steps_per_s={c['steps_per_s']:.1f};"
            f"faults_per_s={c['faults_per_s']:.0f};"
            f"inv_per_step={c['policy_invocations_per_step']:.2f};"
            f"mgmt_wall_p50_us={c['mgmt_wall_p50_ns'] / 1e3:.0f};"
            f"mgmt_wall_p99_us={c['mgmt_wall_p99_ns'] / 1e3:.0f};"
            f"crossings_per_step={c['crossings_per_step']:.2f};"
            f"steady_rows_per_step={c['steady']['rows_per_step']:.2f}")
    for key, s in out["speedup_batched_over_scalar"].items():
        lines.append(f"hotpath_speedup_{key},{s:.2f},batched_over_scalar")
    for lane in out["executors"]["lanes"]:
        lines.append(
            f"executor_{lane['backend']}_b{lane['batch']},"
            f"{lane['us_per_batch']:.1f},"
            f"us_per_decision={lane['us_per_decision']:.1f}")
    lines.append(f"cache_warm_speedup,{out['cache']['warm_speedup']:.2f},"
                 f"build_plus_first_batch cold/warm")
    tl = out["telemetry"]
    lines.append(f"telemetry_off_over_none,{tl['off_over_none']:.3f},"
                 f"steps_per_s ratio (gate >= 0.98)")
    lines.append(f"telemetry_on_over_none,{tl['on_over_none']:.3f},"
                 f"steps_per_s ratio, full tracing")
    lines.append(f"resilience_res_over_none,{tl['res_over_none']:.3f},"
                 f"steps_per_s ratio, chaos disarmed (gate >= 0.98)")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one batch size, fewer steps (CI)")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write the full result dict as JSON")
    args = ap.parse_args()
    result = collect(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(result, f, indent=1)
        print(f"wrote {args.json}")
    print("name,us_per_call,derived")
    for c in result["cells"]:
        disp = c["segment_dispatches_per_step"]
        print(f"hotpath_{c['policy']}_b{c['max_batch']}_{c['mode']},"
              f"{1e6 / c['steps_per_s']:.1f},"
              f"steps_per_s={c['steps_per_s']:.1f};"
              f"faults_per_s={c['faults_per_s']:.0f};"
              f"inv_per_step={c['policy_invocations_per_step']:.2f};"
              f"crossings_per_step={c['crossings_per_step']:.2f};"
              f"steady_rows_per_step={c['steady']['rows_per_step']:.2f}"
              + (f";dispatches_per_step={disp:.2f}"
                 if disp is not None else ""))
    for key, s in result["speedup_batched_over_scalar"].items():
        print(f"hotpath_speedup_{key},{s:.2f},batched_over_scalar")
    ex = result["executors"]
    print(f"# default Fig-1: {ex['unrolled_insns']} unrolled insns -> "
          f"{ex['selected_backend']}, fused={ex['fused']} "
          f"(traced_len={ex['traced_len']}, "
          f"dispatches_per_batch={ex['dispatches_per_batch']})")
    for lane in ex["lanes"]:
        print(f"executor_{lane['backend']}_b{lane['batch']},"
              f"{lane['us_per_batch']:.1f},"
              f"us_per_decision={lane['us_per_decision']:.1f}")
    print(f"cache_warm_speedup,{result['cache']['warm_speedup']:.2f},"
          f"build_plus_first_batch cold/warm")
    tl = result["telemetry"]
    print(f"telemetry_off_over_none,{tl['off_over_none']:.3f},"
          f"steps_per_s ratio (gate >= 0.98)")
    print(f"telemetry_on_over_none,{tl['on_over_none']:.3f},"
          f"steps_per_s ratio, full tracing")
    print(f"resilience_res_over_none,{tl['res_over_none']:.3f},"
          f"steps_per_s ratio, chaos disarmed (gate >= 0.98)")
