"""Capacity sweep: concurrently-resident sequences vs modeled HBM size,
eBPF-guided tiering vs the preempt-only baseline — over 2-, 3- and 4-tier
topologies at EQUAL total spill capacity.

The production question the tiered-memory subsystem answers: how many
sequences can stay RESIDENT (KV materialized in some memory tier, no
recompute-from-scratch on readmission) on a given HBM budget?  The
preempt-only baseline caps residency at what HBM holds and thrashes beyond
it; demote-before-preempt spills cold blocks down the tier chain and keeps
every admitted sequence resident.  The 3-/4-tier rows split the SAME total
spill capacity across peer-HBM (ICI) / host DRAM (PCIe) / NVMe pools driven
by the N-tier placement programs (heat-banded placement, per-edge admission
control), so deeper topologies are judged at equal budget.

Per (hbm_blocks, policy) cell we report: peak concurrently-resident
sequences, preemptions, completions, demotion/promotion traffic, spill-tier
reads, and the modeled device time — so the link tax the tiers pay is
visible next to the preemptions they avoid.

Run:  PYTHONPATH=src python -m benchmarks.capacity_sweep [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import PagedLayout, materialize, model_spec
from repro.serving import Request, ServingEngine

N_REQUESTS = 8
MAX_BATCH = 8
PROMPT_TOKENS = 56
NEW_TOKENS = 10
HOST_BLOCKS = 256          # host-DRAM tier capacity (blocks)
MAX_STEPS = 320

# Every tiered row gets the SAME total spill capacity (HOST_BLOCKS), split
# across deeper chains for the 3-/4-tier topologies: (peer-HBM,) host DRAM
# (, NVMe).
POLICIES = [
    ("preempt-only", dict()),
    ("ebpf-tier", dict(host_blocks=HOST_BLOCKS, tier_policy="ebpf-tier")),
    ("lru-tier", dict(host_blocks=HOST_BLOCKS, tier_policy="lru-tier")),
    ("heat-tier3", dict(tier_blocks=(64, HOST_BLOCKS - 64),
                        tier_policy="heat-tier")),
    ("heat-tier4", dict(tier_blocks=(32, HOST_BLOCKS - 96, 64),
                        tier_policy="heat-tier")),
    ("edge-tier4", dict(tier_blocks=(32, HOST_BLOCKS - 96, 64),
                        tier_policy="edge-tier")),
]

_STATE: dict = {}


def _model():
    if not _STATE:
        cfg = get_smoke_config("deepseek_7b")
        _STATE["cfg"] = cfg
        _STATE["params"] = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    return _STATE["cfg"], _STATE["params"]


def run_cell(hbm_blocks: int, label: str, eng_kw: dict) -> dict:
    cfg, params = _model()
    layout = PagedLayout(num_blocks=hbm_blocks, block_tokens=4, max_blocks=32)
    eng = ServingEngine(cfg, params, layout, max_batch=MAX_BATCH,
                        policy="never", **eng_kw)
    rng = np.random.default_rng(0)
    for r in range(N_REQUESTS):
        eng.submit(Request(
            rid=r, prompt=rng.integers(1, cfg.vocab, PROMPT_TOKENS).tolist(),
            max_new_tokens=NEW_TOKENS, app="chat"))
    peak_resident, steps = 0, 0
    while eng.step():
        peak_resident = max(peak_resident, len(eng.mm.procs))
        steps += 1
        if steps >= MAX_STEPS:
            break
    mm = eng.mm.stats.snapshot()
    return {
        "hbm_blocks": hbm_blocks,
        "policy": label,
        "peak_resident": peak_resident,
        "preemptions": eng.stats.preemptions,
        "tier_reliefs": eng.stats.tier_reliefs,
        "completed": eng.stats.completed,
        "expected": N_REQUESTS,
        "steps": steps,
        "demotion_blocks": mm["demotion_blocks"],
        "tier_promotion_blocks": mm["tier_promotion_blocks"],
        "tier_reads": mm["tier_reads"],
        "modeled_device_us": (mm["mgmt_ns"] + mm["access_ns"]) / 1e3,
    }


def main(smoke: bool = False) -> list[str]:
    hbm_sizes = [48] if smoke else [32, 48, 64, 96]
    lines = []
    for hbm in hbm_sizes:
        cells = {label: run_cell(hbm, label, kw) for label, kw in POLICIES}
        base = cells["preempt-only"]
        tier = cells["ebpf-tier"]
        assert tier["peak_resident"] > base["peak_resident"], (
            f"hbm={hbm}: ebpf-tier must sustain strictly more resident "
            f"sequences ({tier['peak_resident']} vs {base['peak_resident']})")
        # acceptance: a 4-tier chain with an eBPF placement program keeps at
        # least as many sequences resident as the 2-tier baseline at equal
        # total spill capacity
        four = cells["heat-tier4"]
        assert four["peak_resident"] >= tier["peak_resident"], (
            f"hbm={hbm}: 4-tier heat placement must match the 2-tier "
            f"baseline's residency at equal capacity "
            f"({four['peak_resident']} vs {tier['peak_resident']})")
        for label, r in cells.items():
            lines.append(
                f"capacity_hbm{hbm}_{label},{r['modeled_device_us']:.1f},"
                f"resident={r['peak_resident']};preempt={r['preemptions']};"
                f"reliefs={r['tier_reliefs']};"
                f"completed={r['completed']}/{r['expected']};"
                f"dem_blocks={r['demotion_blocks']};"
                f"prom_blocks={r['tier_promotion_blocks']};"
                f"tier_reads={r['tier_reads']};steps={r['steps']}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single HBM size, for CI")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(smoke=args.smoke):
        print(line)
