"""CI gate: telemetry disabled must cost (almost) nothing.

Runs the hotpath bench's telemetry-overhead lane in smoke mode and requires
the constructed-but-disabled Telemetry lane to stay within 2% steps/s of
the no-telemetry baseline (``off_over_none >= 0.98``).  Host jitter on
shared CI runners can flip a marginal run, so the gate takes the BEST of
up to three attempts — a real regression (a tracepoint doing work on the
disabled path) fails all three.

Run:  PYTHONPATH=src python -m benchmarks.telemetry_gate
"""

from __future__ import annotations

import sys

from benchmarks.hotpath_bench import collect_telemetry

THRESHOLD = 0.98
ATTEMPTS = 3


def main() -> int:
    best = None
    for attempt in range(1, ATTEMPTS + 1):
        out = collect_telemetry(smoke=True)
        ratio = out["off_over_none"]
        print(f"attempt {attempt}: off_over_none={ratio:.3f} "
              f"(on_over_none={out['on_over_none']:.3f})")
        if best is None or ratio > best:
            best = ratio
        if ratio >= THRESHOLD:
            print(f"PASS: telemetry-disabled overhead within "
                  f"{(1 - THRESHOLD) * 100:.0f}% of baseline")
            return 0
    print(f"FAIL: off_over_none={best:.3f} < {THRESHOLD} on every attempt "
          f"— the disabled-telemetry path is doing real work")
    return 1


if __name__ == "__main__":
    sys.exit(main())
