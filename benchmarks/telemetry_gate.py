"""CI gate: telemetry disabled AND resilience disarmed must cost (almost)
nothing.

Runs the hotpath bench's overhead lanes in smoke mode and requires both
zero-cost claims to hold within 2% steps/s of the no-instrumentation
baseline:

- ``off_over_none >= 0.98`` — a constructed-but-DISABLED Telemetry (what a
  binary linking the subsystem but not tracing pays);
- ``res_over_none >= 0.98`` — the resilience machinery linked but DISARMED
  (zero-rate FailureInjector through the hook registry, supervisor and
  containment paths live).

Host jitter on shared CI runners can flip a marginal run, so the gate takes
the BEST of up to three attempts per ratio — a real regression (a
tracepoint or injection probe doing work on the disabled path) fails all
three.

Run:  PYTHONPATH=src python -m benchmarks.telemetry_gate
"""

from __future__ import annotations

import sys

from benchmarks.hotpath_bench import collect_telemetry

THRESHOLD = 0.98
ATTEMPTS = 3
GATED = ("off_over_none", "res_over_none")


def main() -> int:
    best = {k: None for k in GATED}
    for attempt in range(1, ATTEMPTS + 1):
        out = collect_telemetry(smoke=True)
        for k in GATED:
            if best[k] is None or out[k] > best[k]:
                best[k] = max(best[k] or 0.0, out[k])
        print(f"attempt {attempt}: " +
              " ".join(f"{k}={out[k]:.3f}" for k in GATED) +
              f" (on_over_none={out['on_over_none']:.3f})")
        if all(best[k] >= THRESHOLD for k in GATED):
            print(f"PASS: disabled-telemetry and disarmed-resilience "
                  f"overhead within {(1 - THRESHOLD) * 100:.0f}% of baseline")
            return 0
    failed = [k for k in GATED if best[k] < THRESHOLD]
    print("FAIL: " +
          ", ".join(f"{k}={best[k]:.3f}" for k in failed) +
          f" < {THRESHOLD} on every attempt — a disabled path is doing "
          f"real work")
    return 1


if __name__ == "__main__":
    sys.exit(main())
