"""Kernel-level benchmarks.

1. Multi-size paged attention: modeled DMA descriptors + effective HBM
   bandwidth per page-size class (the TLB-reach analogue on TPU: larger pages
   = fewer descriptors = closer to peak bandwidth).  The model uses the same
   HWSpec constants as the MM cost model; the Pallas kernel's DMA granularity
   is exactly one page.
2. Wall-clock of the jnp reference paths on CPU (engine-relevant, CSV us).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost import HWSpec
from repro.models.attention import flash_attention
from repro.models.decode import paged_decode_attention_gather


def modeled_paged_read(order: int, *, seq_tokens: int = 32768,
                       kv_heads: int = 8, head_dim: int = 128,
                       block_tokens: int = 16) -> dict:
    hw = HWSpec()
    page_tokens = block_tokens * 4 ** order
    page_bytes = page_tokens * kv_heads * head_dim * 2 * 2
    n_pages = max(1, seq_tokens // page_tokens)
    t_desc = n_pages * hw.descriptor_ns
    t_stream = n_pages * page_bytes / hw.effective_bw(page_bytes) * 1e9
    total_bytes = n_pages * page_bytes
    eff_bw = total_bytes / ((t_desc + t_stream) / 1e9)
    return {"order": order, "pages": n_pages, "page_kb": page_bytes / 1024,
            "read_us": (t_desc + t_stream) / 1e3,
            "eff_bw_gbs": eff_bw / 1e9,
            "bw_frac": eff_bw / hw.hbm_bw}


def main() -> list[str]:
    lines = []
    base = None
    for order in range(4):
        r = modeled_paged_read(order)
        if base is None:
            base = r["read_us"]
        lines.append(
            f"paged_read_order{order},{r['read_us']:.1f},"
            f"pages={r['pages']};page_kb={r['page_kb']:.0f};"
            f"eff_bw={r['eff_bw_gbs']:.0f}GB/s;frac={r['bw_frac']:.2f};"
            f"speedup_vs_o0={base / r['read_us']:.2f}x")

    # CPU wall time of the engine-facing jnp paths
    rng = np.random.default_rng(0)
    B, H, KVH, hd, bt, NB, MB = 4, 8, 4, 64, 16, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, hd)).astype(np.float32))
    pk = jnp.asarray(rng.normal(size=(NB, bt, KVH, hd)).astype(np.float32))
    pv = jnp.asarray(rng.normal(size=(NB, bt, KVH, hd)).astype(np.float32))
    tbl = jnp.asarray(rng.integers(0, NB, size=(B, MB)).astype(np.int32))
    lens = jnp.full((B,), MB * bt, jnp.int32)
    f = jax.jit(lambda *a: paged_decode_attention_gather(
        *a, block_tokens=bt))
    f(q, pk, pv, tbl, lens)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f(q, pk, pv, tbl, lens)[0].block_until_ready()
    us = (time.perf_counter() - t0) / 20 * 1e6
    lines.append(f"paged_gather_jnp_cpu,{us:.0f},B={B};S={MB*bt};KVH={KVH}")

    S = 512
    q2 = jnp.asarray(rng.normal(size=(2, S, 8, 64)).astype(np.float32))
    k2 = jnp.asarray(rng.normal(size=(2, S, 2, 64)).astype(np.float32))
    g = jax.jit(lambda a, b, c: flash_attention(a, b, c, chunk=128))
    g(q2, k2, k2).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(10):
        g(q2, k2, k2).block_until_ready()
    us = (time.perf_counter() - t0) / 10 * 1e6
    lines.append(f"flash_jnp_cpu,{us:.0f},B=2;S={S};H=8;GQA=4x")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
