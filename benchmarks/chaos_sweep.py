"""Chaos sweep: seeded failure injection over the tiered serving engine,
containment ON vs OFF, across a failure-rate ladder.

The resilience subsystem's production claim: under injected faults
(migration copy errors, spill-tier allocation failures, transient tier-link
flaps, hook runtime errors) the CONTAINED engine keeps completing work —
bounded migration retries, per-edge quarantine with hop-over re-routing,
misbehaving-policy detach to the kernel default, and a demote-to-remaining
/ preempt-only degraded ladder — while the no-containment baseline eats
every failure raw (single-shot migrations, no quarantine, policies never
detached).

Per (rate, containment) cell we report: wall-clock steps/s, completions,
preemptions, migration retries/aborts, edge quarantines, policy detaches,
and a timeline of detach/quarantine/readmit events consumed LIVE off the
telemetry ring (``engine.poll_events`` — the same consumer the supervisor
tests use), so recovery is visible as events, not just counters.

Failures are modeled-deterministic: one ``FailureInjector(seed, rates)``
per cell, keyed on (site, pid, addr, modeled-time) — replaying a cell with
the same seed reproduces the identical failure schedule.

Run:  PYTHONPATH=src python -m benchmarks.chaos_sweep [--smoke] [--seed N]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import PagedLayout, materialize, model_spec
from repro.obs import EV_DETACH, EV_QUARANTINE, EV_READMIT, EV_RETRY
from repro.resilience import (SITE_HOOK_RUN, SITE_LINK_FLAP,
                              SITE_MIGRATE_COPY, SITE_TIER_ALLOC,
                              FailureInjector)
from repro.serving import Request, ServingEngine

N_REQUESTS = 6
MAX_BATCH = 6
PROMPT_TOKENS = 56
NEW_TOKENS = 24
HBM_BLOCKS = 48
HOST_BLOCKS = 128
MAX_STEPS = 400

# failure-rate ladder: every chaos site armed at the same per-check rate
RATES = (0.0, 0.05, 0.15, 0.30)
SITES_ARMED = (SITE_MIGRATE_COPY, SITE_TIER_ALLOC, SITE_LINK_FLAP,
               SITE_HOOK_RUN)

_EV_NAMES = {EV_DETACH: "detach", EV_QUARANTINE: "quarantine",
             EV_READMIT: "readmit", EV_RETRY: "retry"}

_STATE: dict = {}


def _model():
    if not _STATE:
        cfg = get_smoke_config("deepseek_7b")
        _STATE["cfg"] = cfg
        _STATE["params"] = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    return _STATE["cfg"], _STATE["params"]


def run_cell(rate: float, containment: bool, seed: int) -> dict:
    cfg, params = _model()
    layout = PagedLayout(num_blocks=HBM_BLOCKS, block_tokens=4, max_blocks=32)
    injector = (FailureInjector(seed, {s: rate for s in SITES_ARMED})
                if rate > 0 else None)
    eng = ServingEngine(cfg, params, layout, max_batch=MAX_BATCH,
                        policy="never", host_blocks=HOST_BLOCKS,
                        tier_policy="ebpf-tier", telemetry=True,
                        chaos=injector, containment=containment)
    rng = np.random.default_rng(seed)
    for r in range(N_REQUESTS):
        eng.submit(Request(
            rid=r, prompt=rng.integers(1, cfg.vocab, PROMPT_TOKENS).tolist(),
            max_new_tokens=NEW_TOKENS, app="chat"))
    steps = 0
    timeline: list[tuple[int, str]] = []        # (modeled ts, event name)
    t0 = time.perf_counter()
    while eng.step():
        steps += 1
        # LIVE ring consumption: drain resilience events as they happen so
        # the detach/quarantine/readmit timeline carries modeled timestamps
        for ev in eng.poll_events():
            name = _EV_NAMES.get(ev["tag"])
            if name is not None:
                timeline.append((ev["ts"], name))
        if steps >= MAX_STEPS:
            break
    wall = time.perf_counter() - t0
    for ev in eng.poll_events():                # drain the tail
        name = _EV_NAMES.get(ev["tag"])
        if name is not None:
            timeline.append((ev["ts"], name))
    m = eng.metrics()
    mm = eng.mm.stats
    counts = {name: sum(1 for _, n in timeline if n == name)
              for name in _EV_NAMES.values()}
    return {
        "rate": rate,
        "containment": containment,
        "steps": steps,
        "steps_per_s": steps / wall if wall > 0 else 0.0,
        "completed": eng.stats.completed,
        "expected": N_REQUESTS,
        "preemptions": eng.stats.preemptions,
        "migrate_retries": mm.migrate_retries,
        "migrate_aborts": mm.migrate_aborts,
        "tier_alloc_failures": mm.tier_alloc_failures,
        "detaches": m.get("resilience_supervisor_detaches", 0),
        "injected": sum(v for k, v in m.items()
                        if k.startswith("resilience_injector") and
                        k.endswith("fired")),
        "events": counts,
        "timeline": timeline[:64],
    }


def main(smoke: bool = False, seed: int = 0) -> list[str]:
    rates = RATES[:3] if smoke else RATES
    lines = []
    for rate in rates:
        cells = {on: run_cell(rate, on, seed)
                 for on in ((True,) if rate == 0.0 else (True, False))}
        contained = cells[True]
        # acceptance: containment never crashes and completes the workload
        # at every injected rate; failures change placement/timing, not
        # whether work finishes
        assert contained["completed"] == contained["expected"], (
            f"rate={rate}: contained engine completed "
            f"{contained['completed']}/{contained['expected']}")
        if rate > 0:
            assert contained["injected"] > 0, (
                f"rate={rate}: injector armed but never fired")
        for on, r in cells.items():
            ev = r["events"]
            lines.append(
                f"chaos_rate{int(rate * 100):02d}_"
                f"{'contained' if on else 'raw'},"
                f"{1e6 / r['steps_per_s']:.1f},"
                f"completed={r['completed']}/{r['expected']};"
                f"preempt={r['preemptions']};"
                f"retries={r['migrate_retries']};"
                f"aborts={r['migrate_aborts']};"
                f"alloc_fail={r['tier_alloc_failures']};"
                f"detaches={r['detaches']};"
                f"injected={r['injected']};"
                f"ev_quarantine={ev['quarantine']};"
                f"ev_readmit={ev['readmit']};"
                f"ev_retry={ev['retry']};ev_detach={ev['detach']}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="truncated rate ladder, for CI")
    ap.add_argument("--seed", type=int, default=0,
                    help="failure-schedule seed (same seed => same schedule)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(smoke=args.smoke, seed=args.seed):
        print(line)
