"""Figure-2 analogue: policy sweep on the serving engine.

The paper's preliminary result (astar, SPEC06): eBPF-mm reaches THP-level
performance while allocating a fraction of the 2MiB pages, by backing only
the AT-intensive regions.  Our workload is the serving version of that
motivation ("different applications benefit from different page sizes"): a
MIXED tenancy of
  * "rag"  — long-context requests: every KV block is re-read each step
             (AT-intensive; huge pages pay off), and
  * "chat" — short-lived requests with reserved-but-unused tail capacity
             (huge pages waste zeroing + compaction under fragmentation),
on a deliberately fragmented pool.  Profiles are DERIVED from a DAMON
profiling pass (profile_from_heat) exactly per the paper's workflow, and one
Fig-1 program serves both apps via the indirect profile-map load.

Reported per policy: modeled device time (management + paged reads),
descriptors touched (TLB-miss analogue), huge-page fraction, compactions,
blocks zeroed — plus the hook-overhead microbench ("zero overhead on
non-hinted faults").
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import (HWSpec, MemoryManager, Profile, ProfileRegion,
                        ebpf_mm_program, make_cost_model, never_program,
                        profile_from_heat)
from repro.core.mm import MMStats
from repro.models import PagedLayout, materialize, model_spec
from repro.serving import Request, ServingEngine

LAYOUT = PagedLayout(num_blocks=256, block_tokens=4, max_blocks=32)


def _submit_workload(eng, cfg, rng) -> int:
    n = 0
    for r in range(3):          # long-context, AT-intensive
        plen = int(rng.integers(80, 112))
        eng.submit(Request(rid=n, prompt=rng.integers(1, cfg.vocab, plen).tolist(),
                           max_new_tokens=16, app="rag"))
        n += 1
    for r in range(5):          # short-lived, early EOS, reserved capacity
        plen = int(rng.integers(8, 20))
        eng.submit(Request(rid=n, prompt=rng.integers(1, cfg.vocab, plen).tolist(),
                           max_new_tokens=48, app="chat",
                           stop_after=int(rng.integers(4, 10))))
        n += 1
    return n


def _fragment_pool(eng) -> None:
    """Fill the pool with order-0 blocks, keep every 4th pinned: free space
    becomes runs of 3 blocks, so every huge-page alloc needs compaction."""
    frag_pids = []
    for i in range(eng.layout.num_blocks):
        pid = 90_000 + i
        eng.mm.create_process(pid, vma_blocks=2)
        try:
            eng.mm.ensure_mapped(pid, 0)
            frag_pids.append(pid)
        except Exception:
            eng.mm.free_process(pid)
            break
    for j, pid in enumerate(frag_pids):
        if j % 4 != 0:
            eng.mm.free_process(pid)


def run_policy(policy: str, *, seed: int = 0, profiles=None) -> dict:
    cfg = get_smoke_config("gemma3_27b")
    params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    eng = ServingEngine(cfg, params, LAYOUT, max_batch=3, policy=policy,
                        profile=profiles, seed=seed)
    rng = np.random.default_rng(seed)
    n_req = _submit_workload(eng, cfg, rng)
    _fragment_pool(eng)
    eng.mm.stats = MMStats()      # measure the serving phase only

    peak_huge, steps = 0.0, 0
    while eng.step():
        peak_huge = max(peak_huge, eng.mm.hugepage_block_fraction())
        steps += 1
        if steps > 600:
            break
    mm = eng.mm.stats.snapshot()
    return {
        "heat_histograms": {k: v / max(1, eng.stats.steps)
                            for k, v in eng.heat_histograms.items()},
        "policy": policy,
        "modeled_device_us": (mm["mgmt_ns"] + mm["access_ns"]) / 1e3,
        "descriptors": mm["descriptors_touched"],
        "peak_huge_fraction": peak_huge,
        "pages_per_order": mm["pages_per_order"],
        "compactions": mm["compactions"],
        "compaction_blocks": mm["compaction_blocks_moved"],
        "blocks_zeroed": mm["blocks_zeroed"],
        "completed": eng.stats.completed,
        "expected": n_req,
        "host_wall_s": eng.stats.wall_host_s,
    }


def derive_profiles(heat_histograms: dict) -> list[Profile]:
    """DAMON replay -> per-app userspace profiles (paper workflow step 2)."""
    cost = make_cost_model(HWSpec(), kv_heads=2, head_dim=16, block_tokens=4)
    profs = []
    for app, hist in sorted(heat_histograms.items()):
        p = profile_from_heat(app, hist, cost, hot_quantile=0.3,
                              min_region_blocks=4)
        profs.append(p if p.regions else Profile(app, []))
    return profs


def bench_hook_overhead(n_faults: int = 2000) -> dict:
    """Per-fault host cost on the SAME allocation pattern (all order-0):
    no program attached (paper's zero-overhead default path) vs a loaded
    never-program (hook + ctx build + VM run) vs the full Fig-1 program."""
    hw = HWSpec()
    out = {}
    for mode in ("default", "never-prog", "ebpf-cold"):
        mm = MemoryManager(2 * n_faults + 64,
                           make_cost_model(hw, kv_heads=8, head_dim=128),
                           default_mode="never")
        if mode == "never-prog":
            mm.attach_fault_program(never_program())
        elif mode == "ebpf-cold":
            prof = Profile("app", [ProfileRegion(0, n_faults + 8,
                                                 (0, 0, 0, 0))])
            mm.load_profile(prof)
            mm.attach_fault_program(ebpf_mm_program())
        mm.create_process(1, app="app" if mode == "ebpf-cold" else None,
                          vma_blocks=n_faults + 8)
        t0 = time.perf_counter()
        for addr in range(n_faults):
            mm.ensure_mapped(1, addr)
        dt = time.perf_counter() - t0
        out[mode] = dt / n_faults * 1e6
    out["hook_overhead_us"] = out["never-prog"] - out["default"]
    out["policy_overhead_us"] = out["ebpf-cold"] - out["default"]
    return out


def main() -> list[str]:
    lines = []
    base = None
    profiles = None
    for policy in ("never", "thp", "ebpf"):
        r = run_policy(policy, profiles=profiles)
        if policy == "never":
            base = r["modeled_device_us"]
            profiles = derive_profiles(r["heat_histograms"])
        speedup = base / max(r["modeled_device_us"], 1e-9)
        lines.append(
            f"fig2_{policy},{r['modeled_device_us']:.1f},"
            f"speedup={speedup:.2f};desc={r['descriptors']};"
            f"huge={r['peak_huge_fraction']:.2f};"
            f"orders={'/'.join(map(str, r['pages_per_order']))};"
            f"compactions={r['compactions']};"
            f"zeroed={r['blocks_zeroed']};"
            f"completed={r['completed']}/{r['expected']}")
    ho = bench_hook_overhead()
    lines.append(f"hook_overhead,{ho['never-prog']:.2f},"
                 f"default_us={ho['default']:.2f};"
                 f"hook_delta_us={ho['hook_overhead_us']:.2f};"
                 f"fig1_policy_us={ho['ebpf-cold']:.2f}")
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
