"""Prefix-cache benchmark: cache-on vs cache-off serving at configurable
shared-prefix traffic shares.

Serving traffic is dominated by shared prefixes (system prompts, few-shot
preambles); the cross-request KV prefix cache maps cached prefix blocks
read-only into new sequences and prefills only the uncached suffix.  This
bench drives the REAL engine (model forward included — the prefill savings
live in the kernel, not the bookkeeping) through seeded request streams
whose shared-prefix share sweeps 0% / 50% / 90%, once with the cache on
and once off, and reports per cell:

  * steps/s and requests/s over the STEADY window — each cell runs one
    untimed pass of the identical-shape traffic first, so every jit
    specialization (full prefill, suffix prefill, decode, the dirty-row /
    delta-triple table buckets, the HOOK_EVICT scan buckets) compiles
    outside the clock and the cache enters the timed pass warm;
  * prefill tokens actually run through the kernel (the savings live
    here: a hit skips the shared span and prefills the suffix only);
  * admission hit rate, tokens skipped, blocks reused, evictions.

The summary derives, per share, the cache-on/off throughput ratio and the
prefill-token reduction — the acceptance numbers (reduction >= 1.5x and
strictly higher steps/s at >= 50% share) the CI gate
(``benchmarks.prefix_gate``) holds.

Run:  PYTHONPATH=src python -m benchmarks.prefix_bench [--json FILE]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

SHARES = (0.0, 0.5, 0.9)
N_REQ = 16
PREFIX_TOKENS = 124          # the shared "system prompt"
TAIL_TOKENS = 4             # unique per-request tail
MAX_NEW = 2
CACHE_BLOCKS = 192
PREFIX_SEED = 7             # the system prompt is FIXED across passes
PASSES = 3                  # timed passes per cell; best-of wins (host jitter)


def make_traffic(seed: int, vocab: int, share: float, n_req: int = N_REQ,
                 rid_base: int = 0, max_new: int = MAX_NEW):
    """Seeded request stream: ``share`` of the requests open with one
    common prefix (fixed tokens — the same system prompt in every pass),
    the rest are fully random prompts of the same total length; tails and
    uniques vary with ``seed``."""
    from repro.serving import Request
    prefix = np.random.default_rng(PREFIX_SEED).integers(
        1, vocab, PREFIX_TOKENS).tolist()
    rng = np.random.default_rng(seed)
    n_shared = int(round(share * n_req))
    kinds = np.array([True] * n_shared + [False] * (n_req - n_shared))
    rng.shuffle(kinds)
    reqs = []
    for r, shared in enumerate(kinds):
        if shared:
            prompt = prefix + rng.integers(1, vocab, TAIL_TOKENS).tolist()
        else:
            prompt = rng.integers(1, vocab,
                                  PREFIX_TOKENS + TAIL_TOKENS).tolist()
        reqs.append(Request(rid=rid_base + r, prompt=prompt,
                            max_new_tokens=max_new, app="chat"))
    return reqs


def _setup():
    from repro.configs.base import get_smoke_config
    from repro.models import PagedLayout, materialize, model_spec
    cfg = get_smoke_config("deepseek_7b")
    params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    layout = PagedLayout(num_blocks=512, block_tokens=4, max_blocks=40)
    return cfg, params, layout


def build_engine(setup, *, cache_on: bool):
    from repro.serving import ServingEngine
    cfg, params, layout = setup
    return ServingEngine(cfg, params, layout, max_batch=4, policy="never",
                         prefix_cache=CACHE_BLOCKS if cache_on else False)


def run_pass(eng, *, share: float, seed: int, rid_base: int) -> dict:
    """One measured pass of the stream through an existing engine.  The
    caller decides whether it counts (pass 0 of a cell is the warmer)."""
    cfg = eng.cfg
    s0 = eng.stats.snapshot()
    pc0 = eng.prefix_cache.snapshot() if eng.prefix_cache else {}
    for req in make_traffic(seed, cfg.vocab, share, rid_base=rid_base):
        eng.submit(req)
    t0 = time.perf_counter()
    out = eng.run(max_steps=5000)
    wall = time.perf_counter() - t0
    s1 = out["engine"]
    assert s1["completed"] - s0["completed"] == N_REQ, "stream did not drain"
    steps = s1["steps"] - s0["steps"]
    res = {
        "requests": N_REQ,
        "steps": steps,
        "steps_per_s": steps / wall,
        "req_per_s": N_REQ / wall,
        "wall_s": wall,
        "prefill_tokens": s1["prefill_tokens"] - s0["prefill_tokens"],
    }
    if eng.prefix_cache is not None:
        pc1 = out["prefix_cache"]
        for k in ("hits", "misses", "tokens_skipped", "blocks_reused",
                  "inserted_blocks", "evict_drops", "evict_demotions"):
            res[k] = pc1[k] - pc0.get(k, 0)
        lk = pc1["lookups"] - pc0.get("lookups", 0)
        res["hit_rate_milli"] = res["hits"] * 1000 // max(1, lk)
    return res


def run_cell(setup, *, cache_on: bool, share: float, seed: int = 0,
             passes: int = PASSES) -> dict:
    eng = build_engine(setup, cache_on=cache_on)
    run_pass(eng, share=share, seed=seed, rid_base=10_000)   # warm, untimed
    cell = None
    for p in range(passes):
        r = run_pass(eng, share=share, seed=seed + 1 + p,
                     rid_base=(p + 1) * 1000)
        if cell is None or r["steps_per_s"] > cell["steps_per_s"]:
            cell = r                       # best-of: wall jitter, not work,
    cell["share"] = share                  # varies between passes
    cell["cache"] = "on" if cache_on else "off"
    return cell


def summarize(cells: list[dict]) -> dict:
    by = {(c["share"], c["cache"]): c for c in cells}
    summary = {}
    for share in sorted({c["share"] for c in cells}):
        on, off = by[(share, "on")], by[(share, "off")]
        summary[f"share_{int(share * 100)}"] = {
            "steps_per_s_ratio": on["steps_per_s"] / off["steps_per_s"],
            "prefill_token_reduction":
                off["prefill_tokens"] / max(1, on["prefill_tokens"]),
            "hit_rate_milli": on.get("hit_rate_milli", 0),
            "tokens_skipped": on.get("tokens_skipped", 0),
        }
    return summary


def run_all(shares=SHARES, seed: int = 0) -> dict:
    setup = _setup()
    cells = []
    for share in shares:
        for cache_on in (False, True):
            cells.append(run_cell(setup, cache_on=cache_on, share=share,
                                  seed=seed))
    return {"bench": "prefix", "cells": cells, "summary": summarize(cells)}


def main(smoke: bool = False):
    doc = run_all(shares=(0.5,) if smoke else SHARES)
    lines = []
    for c in doc["cells"]:
        lines.append(
            f"prefix_s{int(c['share'] * 100)}_{c['cache']},"
            f"{1e6 / c['steps_per_s']:.1f},"
            f"steps_per_s={c['steps_per_s']:.2f};"
            f"prefill_tokens={c['prefill_tokens']};"
            f"hits={c.get('hits', 0)}")
    for name, s in doc["summary"].items():
        lines.append(f"prefix_{name}_summary,0,"
                     f"ratio={s['steps_per_s_ratio']:.3f};"
                     f"reduction={s['prefill_token_reduction']:.2f};"
                     f"hit_rate_milli={s['hit_rate_milli']}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the full result document to FILE")
    ap.add_argument("--smoke", action="store_true",
                    help="single 50%% cell pair only")
    args = ap.parse_args()
    if args.json:
        doc = run_all(shares=(0.5,) if args.smoke else SHARES)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.json}")
        for name, s in doc["summary"].items():
            print(f"  {name}: steps/s ratio {s['steps_per_s_ratio']:.3f}, "
                  f"prefill reduction {s['prefill_token_reduction']:.2f}x, "
                  f"hit rate {s['hit_rate_milli'] / 10:.1f}%")
    else:
        for line in main(smoke=args.smoke):
            print(line)
