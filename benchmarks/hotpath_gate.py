"""CI perf gate: the one-dispatch decode step's hot path must not regress.

Extends the overhead-gate suite (``benchmarks.telemetry_gate``) with the
throughput side: the batched ebpf cell at batch 16 — the cell the
acceptance numbers track — is re-measured and held within 2% steps/s of the
committed ``BENCH_hotpath.json`` baseline, plus two structural invariants
of the one-dispatch step:

- ``segment_dispatches_per_step <= 1`` — the fused ``lax.scan`` policy
  executor issues at most one device dispatch per engine step (a fallback
  to the chained segment loop would trip this long before the wall-clock
  gate notices);
- steady-state table crossings are ZERO — the dirty-row device-table plane
  ships nothing when no sequence crosses a block boundary (a per-step
  recapture sneaking back in ships ``B`` rows every step).

Host jitter on shared CI runners can flip a marginal wall-clock run, so the
throughput ratio takes the BEST of up to three attempts; the structural
invariants must hold on EVERY attempt.

Run:  PYTHONPATH=src python -m benchmarks.hotpath_gate [BASELINE_JSON]
"""

from __future__ import annotations

import json
import pathlib
import sys

from benchmarks.hotpath_bench import N_WINDOWS, STEPS, WARMUP, _Cell

THRESHOLD = 0.98
ATTEMPTS = 3
POLICY, BATCH = "ebpf", 16


def _baseline(path: pathlib.Path) -> float:
    with open(path) as f:
        doc = json.load(f)
    for c in doc["cells"]:
        if (c["policy"] == POLICY and c["max_batch"] == BATCH
                and c["mode"] == "batched"):
            return float(c["steps_per_s"])
    raise SystemExit(f"no batched {POLICY} b{BATCH} cell in {path}")


def _measure() -> dict:
    cell = _Cell(POLICY, BATCH, batched=True, steps=STEPS, warmup=WARMUP)
    for _ in range(N_WINDOWS):
        cell.window()
    return cell.result()


def main(argv: list[str]) -> int:
    path = pathlib.Path(argv[0]) if argv else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_hotpath.json"
    base = _baseline(path)
    best = 0.0
    for attempt in range(1, ATTEMPTS + 1):
        r = _measure()
        ratio = r["steps_per_s"] / base
        best = max(best, ratio)
        disp = r["segment_dispatches_per_step"]
        steady = r["steady"]["rows_per_step"]
        print(f"attempt {attempt}: steps_per_s={r['steps_per_s']:.1f} "
              f"baseline={base:.1f} ratio={ratio:.3f} "
              f"dispatches_per_step={disp:.2f} steady_rows={steady:.2f}")
        if disp is not None and disp > 1.0:
            print(f"FAIL: {disp:.2f} segment dispatches per step — the "
                  f"fused scan executor fell back to the chained loop")
            return 1
        if steady != 0.0:
            print(f"FAIL: {steady:.2f} table rows/step crossed on steady "
                  f"steps — per-step recapture snuck back in")
            return 1
        if best >= THRESHOLD:
            print(f"PASS: batched {POLICY} b{BATCH} within "
                  f"{(1 - THRESHOLD) * 100:.0f}% of the committed baseline")
            return 0
    print(f"FAIL: best ratio {best:.3f} < {THRESHOLD} on every attempt — "
          f"the hot path regressed vs {path.name}")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
