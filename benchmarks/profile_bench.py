"""Online-profiling benchmark: auto-synthesized vs offline vs no profile.

The paper builds its region/benefit profiles OFFLINE (a DAMON profiling
run before serving).  The online profiling plane replaces that step: a
verified profiler program samples the live DAMON regions (HOOK_PROFILE)
and the ProfileSynthesizer hot-reloads synthesized profiles mid-run.  This
bench drives the REAL engine through identical seeded request streams
(one hot shared "system prompt" + unique tails — traffic a profile can
actually exploit) across three lanes:

  * ``offline`` — policy="ebpf" with a hand-built hot-prefix profile
    loaded before the run (the paper's workflow; the quality target);
  * ``auto``    — policy="ebpf", profile="auto": starts with NO profile
    and must converge online (the tentpole under test);
  * ``none``    — the no-profile baseline (base pages, no userspace
    guidance — the kernel-conservative placement a run without any
    profile gets).

Per cell it reports wall steps/s over the steady window (pass 0 warms
every jit bucket AND the auto lane's profile convergence outside the
clock), plus the jitter-free placement metrics the gate leans on: modeled
``access_ns`` (the TLB-reach analogue — deterministic for a seeded
stream), hinted/fallback fault counts, hugepage block fraction, and the
profiler's scan/reload counters.

The summary derives the acceptance numbers the CI gate
(``benchmarks.profile_gate``) holds: auto within 10% of offline steps/s,
auto strictly beating the no-profile lane on modeled access time, and the
profiler demonstrably synthesizing (reloads >= 1, hinted faults > 0).

Run:  PYTHONPATH=src python -m benchmarks.profile_bench [--json FILE]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

LANES = ("offline", "auto", "none")
N_REQ = 12
PREFIX_TOKENS = 56           # the hot shared "system prompt"
TAIL_TOKENS = 8              # unique per-request tail
MAX_NEW = 8
PREFIX_SEED = 7              # the system prompt is FIXED across passes
PASSES = 3                   # timed passes per cell; best-of wins (jitter)
AUTO_PERIOD = 2              # profiler cadence for the auto lane


def make_traffic(seed: int, vocab: int, n_req: int = N_REQ,
                 rid_base: int = 0):
    """Seeded stream: every request opens with one fixed hot prefix (the
    shared span a profile pays off on) followed by a seed-varying tail."""
    from repro.serving import Request
    prefix = np.random.default_rng(PREFIX_SEED).integers(
        1, vocab, PREFIX_TOKENS).tolist()
    rng = np.random.default_rng(seed)
    return [Request(rid=rid_base + r,
                    prompt=prefix + rng.integers(1, vocab,
                                                 TAIL_TOKENS).tolist(),
                    max_new_tokens=MAX_NEW, app="chat")
            for r in range(n_req)]


def _setup():
    from repro.configs.base import get_smoke_config
    from repro.models import PagedLayout, materialize, model_spec
    cfg = get_smoke_config("deepseek_7b")
    params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
    layout = PagedLayout(num_blocks=512, block_tokens=4, max_blocks=40)
    return cfg, params, layout


def offline_profile(layout):
    """The hand-built profile an offline DAMON run of this traffic would
    produce: the shared prefix span is hot (large-page benefit), the tail
    cold."""
    from repro.core import Profile, ProfileRegion
    hot = -(-PREFIX_TOKENS // layout.block_tokens)
    return Profile("chat", [
        ProfileRegion(0, hot, (0, 150_000, 600_000, 2_500_000)),
        ProfileRegion(hot, layout.max_blocks, (0, 0, 0, 0)),
    ])


def build_engine(setup, lane: str):
    from repro.serving import ServingEngine
    cfg, params, layout = setup
    if lane == "offline":
        return ServingEngine(cfg, params, layout, max_batch=4,
                             policy="ebpf", profile=offline_profile(layout))
    if lane == "auto":
        return ServingEngine(cfg, params, layout, max_batch=4,
                             policy="ebpf", profile="auto",
                             profile_period=AUTO_PERIOD)
    if lane == "none":
        return ServingEngine(cfg, params, layout, max_batch=4,
                             policy="never")
    raise ValueError(f"unknown lane {lane!r}")


def run_pass(eng, *, seed: int, rid_base: int) -> dict:
    """One measured pass of the stream through an existing engine.  The
    caller decides whether it counts (pass 0 of a cell is the warmer)."""
    cfg = eng.cfg
    s0 = eng.stats.snapshot()
    m0 = eng.mm.stats.snapshot()
    for req in make_traffic(seed, cfg.vocab, rid_base=rid_base):
        eng.submit(req)
    t0 = time.perf_counter()
    out = eng.run(max_steps=5000)
    wall = time.perf_counter() - t0
    s1, m1 = out["engine"], out["mm"]
    assert s1["completed"] - s0["completed"] == N_REQ, "stream did not drain"
    steps = s1["steps"] - s0["steps"]
    res = {
        "requests": N_REQ,
        "steps": steps,
        "steps_per_s": steps / wall,
        "wall_s": wall,
        "access_ns": m1["access_ns"] - m0["access_ns"],
        "descriptors_touched": (m1["descriptors_touched"]
                                - m0["descriptors_touched"]),
        "hinted_faults": m1["hinted_faults"] - m0["hinted_faults"],
        "fallback_faults": m1["fallback_faults"] - m0["fallback_faults"],
        "huge_fraction": out["huge_fraction"],
    }
    if eng.profiler is not None:
        res["profiler_scans"] = out["profiler"]["scans"]
        res["profiler_reloads"] = out["profiler"]["reloads"]
    return res


def run_cell(setup, *, lane: str, seed: int = 0,
             passes: int = PASSES) -> dict:
    eng = build_engine(setup, lane)
    run_pass(eng, seed=seed, rid_base=10_000)     # warm + converge, untimed
    cell = None
    for p in range(passes):
        r = run_pass(eng, seed=seed + 1 + p, rid_base=(p + 1) * 1000)
        if cell is None or r["steps_per_s"] > cell["steps_per_s"]:
            cell = r                    # best-of: wall jitter, not work,
    cell["lane"] = lane                 # varies between passes
    return cell


def summarize(cells: list[dict]) -> dict:
    by = {c["lane"]: c for c in cells}
    auto, offline, none = by["auto"], by["offline"], by["none"]
    return {
        "auto_vs_offline_steps_ratio":
            auto["steps_per_s"] / offline["steps_per_s"],
        "auto_vs_none_steps_ratio":
            auto["steps_per_s"] / none["steps_per_s"],
        "auto_vs_none_access_ratio":
            auto["access_ns"] / max(1, none["access_ns"]),
        "auto_hinted_faults": auto["hinted_faults"],
        "auto_huge_fraction": auto["huge_fraction"],
        "offline_huge_fraction": offline["huge_fraction"],
        "profiler_reloads": auto.get("profiler_reloads", 0),
        "profiler_scans": auto.get("profiler_scans", 0),
    }


def run_all(lanes=LANES, seed: int = 0) -> dict:
    setup = _setup()
    cells = [run_cell(setup, lane=lane, seed=seed) for lane in lanes]
    return {"bench": "profile", "cells": cells, "summary": summarize(cells)}


def main():
    doc = run_all()
    lines = []
    for c in doc["cells"]:
        lines.append(
            f"profile_{c['lane']},"
            f"{1e6 / c['steps_per_s']:.1f},"
            f"steps_per_s={c['steps_per_s']:.2f};"
            f"access_ns={c['access_ns']};"
            f"hinted={c['hinted_faults']};"
            f"huge_frac={c['huge_fraction']:.3f}")
    s = doc["summary"]
    lines.append(f"profile_summary,0,"
                 f"auto_vs_offline={s['auto_vs_offline_steps_ratio']:.3f};"
                 f"auto_vs_none_access="
                 f"{s['auto_vs_none_access_ratio']:.3f};"
                 f"reloads={s['profiler_reloads']}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write the full result document to FILE")
    args = ap.parse_args()
    if args.json:
        doc = run_all()
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"wrote {args.json}")
        s = doc["summary"]
        print(f"  auto/offline steps/s ratio "
              f"{s['auto_vs_offline_steps_ratio']:.3f}, "
              f"auto/none modeled access "
              f"{s['auto_vs_none_access_ratio']:.3f}, "
              f"reloads {s['profiler_reloads']}, "
              f"hinted faults {s['auto_hinted_faults']}")
    else:
        for line in main():
            print(line)
