"""Policy-VM microbenchmarks: interpreter vs XLA-JIT batch execution.

The beyond-paper claim: batching fault decisions through the compiled VM
amortizes policy cost when hundreds of sequences fault in one engine step.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (ArrayMap, FaultContext, JitPolicy, MapRegistry,
                        PolicyVM, Profile, ProfileRegion, ebpf_mm_program)


def _ctx(addr: int) -> np.ndarray:
    return FaultContext(
        addr=addr, pid=1, vma_start=0, vma_end=4096, fault_max_order=3,
        has_profile=1, profile_map_id=0, profile_nregions=2,
        free_blocks=(100, 25, 6, 1), frag=(0, 100, 400, 900),
        heat=(5, 5, 5, 5), zero_ns_per_block=700, compact_ns_per_block=1300,
        descriptor_ns=800, block_bytes=65536).vector()


def main() -> list[str]:
    maps = MapRegistry()
    m = ArrayMap(512)
    Profile("app", [ProfileRegion(0, 64, (0, 9000, 90000, 900000)),
                    ProfileRegion(64, 4096, (0, 0, 0, 0))]).load_into(m)
    maps.register(m)
    prog = ebpf_mm_program(0)
    vm = PolicyVM(prog, maps)
    jp = JitPolicy(prog, maps)

    n = 512
    ctxs = np.stack([_ctx(a) for a in np.random.default_rng(0)
                     .integers(0, 4096, n)])

    t0 = time.perf_counter()
    for c in ctxs:
        vm.run(c)
    host_us = (time.perf_counter() - t0) / n * 1e6

    jp.run_batch(ctxs)                      # compile
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        jp.run_batch(ctxs)
    batch_us = (time.perf_counter() - t0) / (n * reps) * 1e6

    # predicated (unroll + if-conversion) compiler on an 8-region search
    # program (the full 64-region Fig-1 unroll compiles in minutes on this
    # CPU host — a one-time policy-load cost; see EXPERIMENTS.md §Perf #5)
    from repro.core import Asm, PredicatedPolicy
    from repro.core.vm import HELPER_PROMOTION_COST
    a = Asm()
    a.ldctx("r1", 0)
    a.movi("r8", -1).movi("r4", 0).movi("r3", 8)
    a.label("loop")
    a.mov("r9", "r4").muli("r9", 6)
    a.ldmap("r5", 0, "r9")
    a.jgt("r5", "r1", "nx")
    a.mov("r10", "r9").addi("r10", 1)
    a.ldmap("r5", 0, "r10")
    a.jle("r5", "r1", "nx")
    a.mov("r8", "r9")
    a.ja("done")
    a.label("nx")
    a.addi("r4", 1)
    a.jnzdec("r3", "loop")
    a.label("done")
    a.jlti("r8", 0, "fb")
    a.movi("r1", 1)
    a.call(HELPER_PROMOTION_COST)
    a.exit()
    a.label("fb")
    a.movi("r0", -1)
    a.exit()
    mini = a.build("mini_fig1")
    vm2 = PolicyVM(mini, maps)
    t0 = time.perf_counter()
    for c in ctxs[:128]:
        vm2.run(c)
    mini_host_us = (time.perf_counter() - t0) / 128 * 1e6
    pp = PredicatedPolicy(mini, maps)
    pp.run_batch(ctxs)
    t0 = time.perf_counter()
    for _ in range(reps):
        pp.run_batch(ctxs)
    pred_us = (time.perf_counter() - t0) / (n * reps) * 1e6

    return [
        f"vm_interpreter,{host_us:.2f},per_fault;program_len={len(prog)}",
        f"vm_jit_batch,{batch_us:.3f},per_fault;batch={n};"
        f"speedup={host_us / max(batch_us, 1e-9):.0f}x",
        f"vm_predicated,{pred_us:.3f},per_fault;batch={n};8_region_loop;"
        f"speedup_vs_interp={mini_host_us / max(pred_us, 1e-9):.0f}x",
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
