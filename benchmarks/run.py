"""Benchmark harness — one section per paper table/figure + framework-level
benches.  Prints ``name,us_per_call,derived`` CSV.

Sections:
  fig2_*            the paper's Figure 2 analogue (policy sweep: speedup,
                    TLB-analogue descriptors, huge-page fraction) + the
                    hook-overhead microbench ("zero overhead on non-hinted
                    faults").
  capacity_*        tiered-memory capacity sweep: concurrently-resident
                    sequences vs HBM size, ebpf-tier vs preempt-only
                    (demote-before-preempt over the host-DRAM tier).
  hotpath_*         per-engine-step management cost: batched fault path
                    (one policy invocation per step) vs the pre-PR scalar
                    path, per policy and batch size.
  prefix_*          cross-request KV prefix cache: cache-on vs cache-off
                    steps/s and prefill tokens at configurable
                    shared-prefix traffic share.
  vm_*              eBPF-VM interpreter vs XLA-JIT batch execution.
  paged_read_*      multi-size page DMA model (descriptor amortization /
                    effective HBM bandwidth per page size — the TLB-reach
                    analogue driving the benefit model).
  *_cpu             wall-clock of the engine-facing jnp paths on this host.
  roofline          summary of results/dryrun (if present): per-cell dominant
                    terms (full table via `python -m benchmarks.roofline`).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_kernels, bench_vm, capacity_sweep,
                   fig2_policy_sweep, hotpath_bench, prefix_bench)

    print("name,us_per_call,derived")
    sections = [
        ("fig2", fig2_policy_sweep.main),
        ("capacity", lambda: capacity_sweep.main(smoke=True)),
        ("hotpath", lambda: hotpath_bench.main(smoke=True)),
        ("prefix", lambda: prefix_bench.main(smoke=True)),
        ("vm", bench_vm.main),
        ("kernels", bench_kernels.main),
    ]
    failures = 0
    for name, fn in sections:
        try:
            for line in fn():
                print(line)
        except Exception as e:   # noqa: BLE001
            failures += 1
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)

    # roofline summary (if the dry-run artifacts exist)
    try:
        from .roofline import build_table
        rows = build_table("results/dryrun", mesh="single")
        if rows:
            doms = {}
            fracs = []
            for r in rows:
                doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
                fracs.append(r["roofline_fraction"])
            dom_s = "/".join(f"{k}:{v}" for k, v in sorted(doms.items()))
            print(f"roofline_cells,{len(rows)},dominant={dom_s};"
                  f"median_frac={sorted(fracs)[len(fracs)//2]:.2f}")
    except Exception as e:   # noqa: BLE001
        print(f"roofline_summary,0,unavailable:{type(e).__name__}")

    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
