"""CI perf gate: online profile synthesis must match offline profiling.

Holds the acceptance numbers of the online-profiling PR — a run started
with NO profile (``profile="auto"``) must converge to DAMON-quality
placement:

- auto steps/s >= 90% of the offline-profile lane's (the profiler scan +
  synthesis overhead stays inside the 10% budget) — wall-clock, so the
  ratio takes the BEST of up to three attempts (host jitter);
- auto modeled ``access_ns`` STRICTLY below the no-profile lane's on
  EVERY attempt — the synthesized profile must actually buy the paper's
  TLB-reach benefit (deterministic for a seeded stream: jitter-free);
- the plane demonstrably ran: profiler reloads >= 1 and hinted faults
  > 0 on every attempt (a silently-detached profiler or a profile that
  never hints trips this long before the wall-clock does);
- the committed ``BENCH_profile.json`` ratio is a floor (minus a jitter
  allowance): a regression that taxes the auto lane shows up against the
  artifact even while still above the 0.9 line.

Profiling-DISABLED overhead is not re-measured here: an engine without
``profile="auto"`` constructs no synthesizer and attaches no profiler
program, so its hot path is covered by the existing 2% telemetry gate
(``benchmarks.telemetry_gate``) that CI already runs.

Run:  PYTHONPATH=src python -m benchmarks.profile_gate [BASELINE_JSON]
"""

from __future__ import annotations

import json
import pathlib
import sys

from benchmarks.profile_bench import _setup, build_engine, run_pass

ATTEMPTS = 3
RATIO_MIN = 0.9                 # auto within 10% of offline steps/s
BASELINE_SLACK = 0.1            # jitter allowance under the committed ratio
                                # (wall ratios between two lanes swing far
                                # more than a single-lane benchmark's)


def _baseline_ratio(path: pathlib.Path) -> float:
    """Committed auto/offline steps/s ratio; 0 if no artifact."""
    if not path.exists():
        return 0.0
    with open(path) as f:
        doc = json.load(f)
    return float(doc["summary"].get("auto_vs_offline_steps_ratio", 0.0))


def main(argv: list[str]) -> int:
    path = pathlib.Path(argv[0]) if argv else \
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_profile.json"
    floor = max(RATIO_MIN, _baseline_ratio(path) - BASELINE_SLACK)
    setup = _setup()
    engines = {lane: build_engine(setup, lane)
               for lane in ("offline", "auto", "none")}
    for eng in engines.values():   # warm: compiles + profile convergence
        run_pass(eng, seed=0, rid_base=90_000)
    best = 0.0
    for attempt in range(1, ATTEMPTS + 1):
        r = {lane: run_pass(eng, seed=attempt, rid_base=attempt * 1000)
             for lane, eng in engines.items()}
        ratio = r["auto"]["steps_per_s"] / r["offline"]["steps_per_s"]
        best = max(best, ratio)
        reloads = r["auto"].get("profiler_reloads", 0)
        print(f"attempt {attempt}: auto={r['auto']['steps_per_s']:.1f} "
              f"offline={r['offline']['steps_per_s']:.1f} steps/s "
              f"ratio={ratio:.3f} "
              f"access auto={r['auto']['access_ns']} "
              f"none={r['none']['access_ns']} "
              f"hinted={r['auto']['hinted_faults']} reloads={reloads}")
        if reloads < 1:
            print("FAIL: the profiler never reloaded a synthesized profile "
                  "— the online plane is not running")
            return 1
        if r["auto"]["hinted_faults"] <= 0:
            print("FAIL: no hinted faults in the auto lane — the "
                  "synthesized profile is not reaching the fault program")
            return 1
        if r["auto"]["access_ns"] >= r["none"]["access_ns"]:
            print(f"FAIL: auto modeled access {r['auto']['access_ns']} ns "
                  f">= no-profile {r['none']['access_ns']} ns — the "
                  f"synthesized profile buys no placement benefit")
            return 1
        if best >= floor:
            print(f"PASS: auto within {(1 - best) * 100:.1f}% of the "
                  f"offline-profile lane (best ratio {best:.3f} >= "
                  f"{floor:.3f}) and strictly beats no-profile on modeled "
                  f"access time")
            return 0
    print(f"FAIL: best auto/offline steps/s ratio {best:.3f} < {floor:.3f} "
          f"on every attempt — online profiling no longer keeps up with "
          f"the offline workflow")
    return 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
