"""Quickstart: the paper's mechanism end to end, no model involved.

1. build a MemoryManager over a block pool,
2. load a userspace profile into an eBPF map,
3. attach the (verified) Figure-1 policy program to the fault hook,
4. fault pages, watch profile-guided size decisions,
5. let khugepaged collapse a DAMON-hot region.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (HWSpec, Khugepaged, MemoryManager, Profile,
                        ProfileRegion, ebpf_mm_program, make_cost_model)

hw = HWSpec()
cost = make_cost_model(hw, kv_heads=8, head_dim=128)   # KV slab geometry
mm = MemoryManager(num_blocks=4096, cost=cost, default_mode="thp")

# userspace: "blocks 0..256 are AT-intensive; the tail is cold"
profile = Profile("my-llm", [
    ProfileRegion(0, 256, benefit=(0, 50_000, 400_000, 2_000_000)),
    ProfileRegion(256, 2048, benefit=(0, 0, 0, 0)),
])
mm.load_profile(profile)

# load-time verification happens here (VerifierError on a bad program)
program = ebpf_mm_program()
print(f"program: {len(program)} insns, verified OK")
mm.attach_fault_program(program)

mm.create_process(pid=1, app="my-llm", vma_blocks=2048)
hot = mm.ensure_mapped(1, 0)       # fault in the hot region
cold = mm.ensure_mapped(1, 300)    # fault in the cold region
print(f"hot fault  -> order {hot.order} page "
      f"({16 * 4 ** hot.order} tokens), hinted={hot.hinted}")
print(f"cold fault -> order {cold.order} page "
      f"({16 * 4 ** cold.order} tokens), hinted={cold.hinted}")

# bulk prefill + access monitoring + background promotion
mm.ensure_range(1, 256, 512)                     # cold -> base pages
heat = np.zeros(2048)
heat[256:320] = 40.0                             # region turns hot at runtime
for _ in range(6):
    mm.record_access(1, heat)
kh = Khugepaged(mm)
collapsed = sum(kh.tick() for _ in range(4))
print(f"khugepaged collapsed {collapsed} hot regions "
      f"(promotions={mm.stats.promotions})")
print(f"device move list for the block-copy kernel: "
      f"{len(mm.drain_moves())} migrations")
print("MM stats:", mm.stats.snapshot())
