"""The paper's complete workflow (Figure 1 + the evaluation loop):

  1. PROFILE: run the workload under the default policy with DAMON recording
     (the engine aggregates per-block attention mass per application),
  2. DERIVE: profile_from_heat turns the trace into userspace profiles
     (regions x expected benefit per page size),
  3. DEPLOY: load the profiles + the verified Figure-1 program and serve —
     then compare never / THP / eBPF-mm on the Figure-2 metrics.

Run:  PYTHONPATH=src python examples/profile_guided_serving.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.fig2_policy_sweep import (bench_hook_overhead,
                                          derive_profiles, run_policy)

print("== 1-2. profiling pass (policy=never) + DAMON replay ==")
base = run_policy("never")
profiles = derive_profiles(base["heat_histograms"])
for p in profiles:
    print(f"  app {p.app!r}: {len(p.regions)} regions")
    for r in p.regions:
        print(f"    blocks [{r.start},{r.end})  benefit/order {r.benefit}")

print("\n== 3. policy sweep (Figure-2 metrics) ==")
rows = {"never": base}
for policy in ("thp", "ebpf"):
    rows[policy] = run_policy(policy, profiles=profiles)
print(f"{'policy':8s}{'modeled_us':>12s}{'speedup':>9s}{'descriptors':>13s}"
      f"{'huge_frac':>11s}{'zeroed':>8s}{'compactions':>13s}")
for name, r in rows.items():
    sp = base["modeled_device_us"] / max(r["modeled_device_us"], 1e-9)
    print(f"{name:8s}{r['modeled_device_us']:>12.1f}{sp:>9.2f}"
          f"{r['descriptors']:>13d}{r['peak_huge_fraction']:>11.2f}"
          f"{r['blocks_zeroed']:>8d}{r['compactions']:>13d}")

print("\n== hook overhead (the 'zero overhead on non-hinted faults' claim) ==")
ho = bench_hook_overhead(n_faults=500)
print(f"  default path : {ho['default']:.1f} us/fault (no ctx built)")
print(f"  hooked       : {ho['never-prog']:.1f} us/fault")
print(f"  Fig-1 program: {ho['ebpf-cold']:.1f} us/fault")
