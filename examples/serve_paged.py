"""End-to-end serving driver: continuous batching over a real (smoke-size)
model with the eBPF-mm paged KV cache — batched requests, page faults on
block crossings, DAMON heat from attention mass, and (with --host-blocks)
the tiered-memory subsystem: under pressure cold KV blocks are demoted to a
host-DRAM tier over PCIe instead of preempting whole sequences, and promoted
back when they re-heat.  Without a host tier, preemption under pressure.

Run:  PYTHONPATH=src python examples/serve_paged.py [--arch gemma3_27b]
      PYTHONPATH=src python examples/serve_paged.py \
          --hbm-blocks 48 --host-blocks 256 --tier ebpf-tier   # 2-tier
      PYTHONPATH=src python examples/serve_paged.py \
          --hbm-blocks 48 --tier-blocks 32,160,64 \
          --tier heat-tier                     # 4-tier: +peer-HBM, +NVMe
      PYTHONPATH=src python examples/serve_paged.py \
          --trace out/trace.json --metrics out/metrics.txt  # telemetry:
          # Chrome trace (load in Perfetto) + Prometheus-style metrics
      PYTHONPATH=src python examples/serve_paged.py \
          --hbm-blocks 48 --host-blocks 256 --chaos 7   # chaos: seeded
          # deterministic fault injection + live ring-event consumption
      PYTHONPATH=src python examples/serve_paged.py \
          --profile auto --trace out/trace.json \
          --wss-curve out/wss.json   # online profiling: no profile loaded,
          # a verified profiler program samples the live DAMON regions and
          # synthesized profiles hot-reload mid-run (WSS curve + reloads
          # appear on the trace's "mm profiler" track)
"""

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import Profile, ProfileRegion
from repro.models import PagedLayout, materialize, model_spec
from repro.serving import Request, ServingEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3_27b")
ap.add_argument("--policy", default="ebpf",
                choices=["ebpf", "thp", "never"])
ap.add_argument("--profile", default="demo", metavar="auto|FILE|none",
                help="profile source for --policy ebpf: 'auto' = online "
                     "synthesis (a verified profiler program samples the "
                     "live DAMON regions and synthesized profiles hot-"
                     "reload mid-run), FILE = a profile JSON "
                     "(Profile.to_json), 'none' = no profile (non-ebpf "
                     "policies only), default = the built-in hot-prefix "
                     "demo profile")
ap.add_argument("--wss-curve", default="", metavar="FILE",
                help="with --profile auto: dump the online profiler's "
                     "per-process WSS curve JSON to FILE at exit")
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--hbm-blocks", type=int, default=512,
                help="modeled HBM pool size in blocks")
ap.add_argument("--host-blocks", type=int, default=0,
                help="host-DRAM tier size in blocks (0 = no tiering)")
ap.add_argument("--tier-blocks", default="",
                help="comma-separated spill-tier capacities for an N-pool "
                     "chain, e.g. '64,192,256' = peer-HBM, host DRAM, NVMe "
                     "(overrides --host-blocks)")
ap.add_argument("--tier", default="ebpf-tier",
                choices=["ebpf-tier", "lru-tier", "never-tier", "heat-tier",
                         "edge-tier", "default"],
                help="mm_tier hook policy (used when a tier chain is set)")
ap.add_argument("--prefix-cache", type=int, default=0, metavar="BLOCKS",
                help="enable the cross-request KV prefix cache with an HBM "
                     "budget of BLOCKS; requests then share a common system "
                     "prompt so later admissions hit")
ap.add_argument("--prefix-share", type=float, default=0.5,
                help="fraction of requests opening with the shared prefix "
                     "(with --prefix-cache; default 0.5)")
ap.add_argument("--evict-policy", default="lru-evict",
                choices=["lru-evict", "lfu-evict", "ghost-evict", "default"],
                help="HOOK_EVICT program deciding which cached prefixes to "
                     "demote/drop (with --prefix-cache)")
ap.add_argument("--scalar-faults", action="store_true",
                help="pre-batching fault path: one policy invocation per "
                     "fault instead of one per engine step")
ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                help="arm the deterministic failure injector with SEED: "
                     "migration copy errors, tier-alloc failures, link "
                     "flaps, hook runtime errors (same seed = same "
                     "failure schedule)")
ap.add_argument("--chaos-rate", type=float, default=0.02,
                help="per-site failure probability for --chaos "
                     "(default 0.02)")
ap.add_argument("--no-containment", action="store_true",
                help="disable the resilience machinery (no retry/backoff, "
                     "no quarantine, no policy detach) — the chaos "
                     "baseline lane")
ap.add_argument("--trace", default="", metavar="FILE",
                help="enable telemetry and write a Chrome trace-event JSON "
                     "(engine spans + mm/program ring events) to FILE")
ap.add_argument("--metrics", nargs="?", const="-", default="", metavar="FILE",
                help="enable telemetry and dump a Prometheus-style metrics "
                     "snapshot to FILE (default: stdout)")
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
tier_blocks = tuple(int(b) for b in args.tier_blocks.split(",") if b) or None
tier_note = (f", {args.tier} over tiers {tier_blocks}" if tier_blocks
             else f", {args.tier} over {args.host_blocks} host blocks"
             if args.host_blocks else "")
print(f"serving {cfg.name} ({args.policy} policy{tier_note})")
params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
layout = PagedLayout(num_blocks=args.hbm_blocks, block_tokens=4,
                     max_blocks=32)

if args.policy != "ebpf" or args.profile == "none":
    if args.policy == "ebpf":
        ap.error("--profile none requires a non-ebpf --policy "
                 "(the eBPF policy needs a profile source; try "
                 "--profile auto)")
    profile = None
elif args.profile == "auto":
    profile = "auto"
elif args.profile == "demo":
    profile = Profile("chat", [
        ProfileRegion(0, 8, (0, 150_000, 600_000, 2_500_000)),  # hot prefix
        ProfileRegion(8, 32, (0, 0, 0, 0)),                     # cold tail
    ])
else:
    with open(args.profile) as f:
        profile = Profile.from_json(f.read())

telemetry = True if (args.trace or args.metrics or
                     args.chaos is not None) else None
engine = ServingEngine(cfg, params, layout, max_batch=4, policy=args.policy,
                       profile=profile, host_blocks=args.host_blocks,
                       tier_blocks=tier_blocks, tier_policy=args.tier,
                       batch_faults=not args.scalar_faults,
                       telemetry=telemetry, trace=bool(args.trace),
                       chaos=args.chaos, chaos_rate=args.chaos_rate,
                       containment=not args.no_containment,
                       prefix_cache=args.prefix_cache or False,
                       evict_policy=args.evict_policy)
if args.prefix_cache:
    print(f"prefix cache: {args.prefix_cache} HBM blocks, "
          f"{args.evict_policy}, {args.prefix_share:.0%} shared traffic")
if args.chaos is not None:
    print(f"chaos armed: seed={args.chaos} rate={args.chaos_rate} "
          f"containment={'off' if args.no_containment else 'on'}")
rng = np.random.default_rng(0)
shared_prefix = rng.integers(1, cfg.vocab, 24).tolist()
for r in range(args.requests):
    if args.prefix_cache and rng.random() < args.prefix_share:
        prompt = shared_prefix + rng.integers(1, cfg.vocab, 8).tolist()
    else:
        plen = int(rng.integers(16, 48))
        prompt = rng.integers(1, cfg.vocab, plen).tolist()
    engine.submit(Request(
        rid=r, prompt=prompt,
        max_new_tokens=24, app="chat", temperature=0.0))

# With chaos armed (and no trace export pending — poll_events drains the
# ring destructively) consume the event ring LIVE every few steps, the way
# a monitoring sidecar would: detach / quarantine / retry events surface
# mid-run instead of only in the end-of-run snapshot.
live_counts: dict[str, int] = {}
if args.chaos is not None and not args.trace:
    steps = 0
    while engine.step() and steps < 10_000:
        steps += 1
        if steps % 8 == 0:
            for ev in engine.poll_events():
                live_counts[ev["name"]] = live_counts.get(ev["name"], 0) + 1
    for ev in engine.poll_events():
        live_counts[ev["name"]] = live_counts.get(ev["name"], 0) + 1
    out = {"engine": engine.stats.snapshot(),
           "mm": engine.mm.stats.snapshot(),
           "huge_fraction": engine.mm.hugepage_block_fraction()}
else:
    out = engine.run()
print(json.dumps(out, indent=1, default=float))
if args.chaos is not None:
    m = engine.metrics()
    resil = {k: v for k, v in sorted(m.items())
             if k.startswith("resilience_") and v}
    print("resilience:", json.dumps(resil, default=float))
    if live_counts:
        print("live ring events:",
              json.dumps(dict(sorted(live_counts.items()))))
for rid in sorted(engine.finished)[:3]:
    print(f"request {rid}: generated {engine.finished[rid][:10]}...")

if engine.profiler is not None:
    p = engine.profiler.snapshot()
    print(f"online profiler: {p['scans']} scans, {p['reloads']} reloads, "
          f"apps={json.dumps(p['apps'])}")
    if args.wss_curve:
        engine.write_wss_curve(args.wss_curve)
        print(f"wrote WSS curve to {args.wss_curve}")
if args.trace:
    engine.write_trace(args.trace)
    print(f"wrote Chrome trace to {args.trace} (open in ui.perfetto.dev)")
if args.metrics:
    text = engine.metrics_text()
    if args.metrics == "-":
        print(text, end="")
    else:
        with open(args.metrics, "w") as f:
            f.write(text)
        print(f"wrote metrics snapshot to {args.metrics}")
