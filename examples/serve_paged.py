"""End-to-end serving driver: continuous batching over a real (smoke-size)
model with the eBPF-mm paged KV cache — batched requests, page faults on
block crossings, DAMON heat from attention mass, preemption under pressure.

Run:  PYTHONPATH=src python examples/serve_paged.py [--arch gemma3_27b]
"""

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import Profile, ProfileRegion
from repro.models import PagedLayout, materialize, model_spec
from repro.serving import Request, ServingEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3_27b")
ap.add_argument("--policy", default="ebpf",
                choices=["ebpf", "thp", "never"])
ap.add_argument("--requests", type=int, default=8)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
print(f"serving {cfg.name} ({args.policy} policy)")
params = materialize(jax.random.PRNGKey(0), model_spec(cfg))
layout = PagedLayout(num_blocks=512, block_tokens=4, max_blocks=32)

profile = Profile("chat", [
    ProfileRegion(0, 8, (0, 150_000, 600_000, 2_500_000)),   # hot prefix
    ProfileRegion(8, 32, (0, 0, 0, 0)),                      # cold tail
]) if args.policy == "ebpf" else None

engine = ServingEngine(cfg, params, layout, max_batch=4, policy=args.policy,
                       profile=profile)
rng = np.random.default_rng(0)
for r in range(args.requests):
    plen = int(rng.integers(16, 48))
    engine.submit(Request(
        rid=r, prompt=rng.integers(1, cfg.vocab, plen).tolist(),
        max_new_tokens=24, app="chat", temperature=0.0))

out = engine.run()
print(json.dumps(out, indent=1, default=float))
for rid in sorted(engine.finished)[:3]:
    print(f"request {rid}: generated {engine.finished[rid][:10]}...")
