"""Train a (reduced) LM for a few hundred steps with the fault-tolerant
Trainer: synthetic bigram data (loss genuinely decreases), AdamW + cosine
schedule, crash-safe checkpoints — including a simulated mid-run failure
that the loop recovers from automatically.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch deepseek_7b]
"""

import argparse
import tempfile

import jax

from repro.checkpoint.store import CheckpointStore
from repro.configs.base import get_smoke_config
from repro.data.pipeline import make_batch_iter
from repro.distributed.fault import SimulatedFailure
from repro.models import materialize, model_spec, param_count
from repro.training.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="deepseek_7b")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
spec = model_spec(cfg)
params = materialize(jax.random.PRNGKey(0), spec)
print(f"training {cfg.name}: {param_count(spec):,} params, "
      f"{args.steps} steps of {args.batch}x{args.seq}")

crash = {"armed": True}


def chaos(step):   # one simulated node failure mid-run
    if step == args.steps // 2 and crash["armed"]:
        crash["armed"] = False
        print(f"  !! simulated failure at step {step} — restoring from ckpt")
        raise SimulatedFailure()


ckpt_dir = tempfile.mkdtemp(prefix="ebpfmm_train_")
trainer = Trainer(
    TrainerConfig(num_steps=args.steps, checkpoint_every=25, log_every=20,
                  base_lr=1e-3, chunk=min(512, args.seq)),
    cfg, params, make_batch_iter(cfg, args.batch, args.seq),
    CheckpointStore(ckpt_dir), failure_hook=chaos)
out = trainer.run()

for m in out["metrics"]:
    print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
          f"lr {m['lr']:.2e}  {m['sec']*1e3:.0f} ms")
first, last = out["metrics"][0], out["metrics"][-1]
print(f"loss {first['loss']:.3f} -> {last['loss']:.3f}; "
      f"restarts={out['restarts']}; checkpoints in {ckpt_dir}")
assert last["loss"] < first["loss"], "loss should decrease"
